"""Minimal msgpack checkpointing for param/optimizer pytrees."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(obj):
    if isinstance(obj, (np.ndarray, jnp.ndarray)):
        arr = np.asarray(obj)
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # non-native dtypes (bf16) are stored upcast; load_checkpoint
            # casts back to the target tree's dtype
            arr = arr.astype(np.float32)
        return {b"__nd__": True, b"dtype": arr.dtype.str,
                b"shape": list(arr.shape), b"data": arr.tobytes()}
    return obj


def _decode(obj):
    if isinstance(obj, dict) and obj.get(b"__nd__"):
        return np.frombuffer(obj[b"data"], dtype=np.dtype(obj[b"dtype"])) \
            .reshape(obj[b"shape"]).copy()
    return obj


def save_checkpoint(path: str, tree: Any) -> None:
    flat, treedef = jax.tree.flatten(tree)
    payload = {"leaves": [_encode(np.asarray(x)) for x in flat],
               "treedef": str(treedef)}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, default=_encode, use_bin_type=True))


def load_checkpoint(path: str, like: Any) -> Any:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), object_hook=_decode, raw=True)
    flat_like, treedef = jax.tree.flatten(like)
    leaves = [_decode(x) if not isinstance(x, np.ndarray) else x
              for x in payload[b"leaves"]]
    assert len(leaves) == len(flat_like), "checkpoint/tree mismatch"
    leaves = [jnp.asarray(l).astype(x.dtype).reshape(x.shape)
              for l, x in zip(leaves, flat_like)]
    return jax.tree.unflatten(treedef, leaves)
