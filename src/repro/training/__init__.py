from .optimizer import AdamWConfig, init_opt_state, adamw_update, schedule
from .train_loop import make_train_step, init_train_state
from .checkpoint import save_checkpoint, load_checkpoint
