"""Training step with microbatch gradient accumulation + ZeRO-1 state sharding."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import ModelConfig, ShardCtx, loss_fn
from .optimizer import AdamWConfig, adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    shd: ShardCtx, num_microbatches: int = 1,
                    grad_specs=None):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {params, opt}; batch = {tokens (B,S), [prefix_embeds]}.
    Gradients are accumulated over ``num_microbatches`` sequential slices of
    the per-device batch (bounds activation live range), then AdamW applies.
    ``grad_specs`` (a pytree of PartitionSpec) constrains the f32 accumulators
    to the ZeRO-1 layout so the accumulation buffer is sharded too.
    """

    def constrain(tree):
        if grad_specs is None or shd.mesh is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(shd.mesh, s)), tree, grad_specs)

    def micro_loss(params, micro):
        loss, metrics = loss_fn(params, cfg, micro, shd)
        return loss, metrics

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        mb = num_microbatches

        if mb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                micro_loss, has_aux=True)(params, batch)
            grads = constrain(grads)
        else:
            def split(x):
                B = x.shape[0]
                return x.reshape(mb, B // mb, *x.shape[1:])

            micros = jax.tree.map(split, batch)
            g0 = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))

            def body(carry, micro):
                gacc, lacc = carry
                (l, m), g = jax.value_and_grad(micro_loss, has_aux=True)(
                    params, micro)
                gacc = constrain(jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g))
                return (gacc, lacc + l), None

            (grads, loss_sum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), micros)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss_sum / mb
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        new_params, new_opt, opt_metrics = adamw_update(params, grads, opt, opt_cfg)
        out = {"loss": loss, **metrics, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, out

    return train_step


def init_train_state(key, cfg: ModelConfig):
    from repro.models import init_params
    params = init_params(key, cfg)
    return {"params": params, "opt": init_opt_state(params)}
