"""AdamW + cosine LR schedule (pure pytree implementation, no optax)."""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(math.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt, cfg: AdamWConfig):
    step = opt["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gn, "lr": lr}
