"""Shared serving-plane types: requests, SLOs, and the request-lifecycle
vocabulary (states, sampling parameters, stream events) spoken by every
backend (``serving.engine``, ``serving.cluster``, ``sim.engine``) and by the
``serving.api`` front door."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Tuple


class RequestState(str, enum.Enum):
    """Lifecycle of a request inside any serving backend.

    QUEUED -> PREFILLING -> DECODING -> FINISHED is the happy path; a
    preempted stream returns to QUEUED (recompute-on-resume keeps its
    emitted tokens), and ``cancel`` moves any non-terminal state to
    CANCELLED (terminal).  One-shot (non-chunked) prefills jump straight
    from QUEUED to DECODING — PREFILLING marks the *observable* mid-chunk
    window, not an accounting phase.

    Two failure terminals complete the lifecycle: FAILED marks a request
    the system gave up on (``Backend.fail`` — the ``Server.run`` watchdog
    uses it for streams past their wall budget or stuck backends), SHED a
    request dropped by deadline-aware admission (its absolute ``deadline``
    had already passed when it reached the head of the queue — serving it
    could only burn energy on a guaranteed SLO miss).  Both are clean
    releases: slot, page chain and recurrent row state are freed exactly
    like a cancel, and tokens already emitted stay readable.
    """
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"
    CANCELLED = "cancelled"
    FAILED = "failed"          # watchdog / backend gave up (terminal)
    SHED = "shed"              # dropped by deadline-aware admission (terminal)

    @property
    def terminal(self) -> bool:
        return self in (RequestState.FINISHED, RequestState.CANCELLED,
                        RequestState.FAILED, RequestState.SHED)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling surface of ``serving.api.Server.submit``.

    Sampling is a *per-slot vectorized* property of the real-execution
    engines' jitted decode path: requests with different temperatures,
    top-k and top-p settings share one batch (the per-row lanes live in
    device vectors, never as static jit arguments).  ``temperature=None``
    means greedy argmax, exactly like ``temperature=0`` (there is no
    engine-global default to inherit — sampling is per-request only).
    ``top_k=0`` and ``top_p=1.0`` disable the respective filter.
    ``seed`` pins the request's PRNG lane — a seeded sampled stream draws
    the same tokens across runs, migrations and preempt/recompute resumes
    (see ``serving.engine``: draw ``i`` uses ``fold_in(lane, position_i)``,
    so the lane itself never advances).
    """
    max_tokens: int = 64           # output length cap (the request's budget)
    temperature: Optional[float] = None   # None -> backend default; 0 -> greedy
    top_k: int = 0                 # keep the k highest logits (0: disabled)
    top_p: float = 1.0             # nucleus mass to keep (1.0: disabled)
    seed: Optional[int] = None     # PRNG lane seed (None: derived from rid)

    def __post_init__(self):
        if self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        if self.temperature is not None and self.temperature < 0.0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(
                f"top_p must be in (0, 1], got {self.top_p}")


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float            # s
    prompt_len: int
    output_len: int           # ground truth; unknown to the system a priori
    # runtime bookkeeping
    prefill_start: float = -1.0
    first_token: float = -1.0  # TTFT timestamp
    finish: float = -1.0
    tokens_emitted: int = 0
    cls: str = ""              # routing class ("SM" | "L")
    state: RequestState = RequestState.QUEUED
    deadline: float = -1.0     # optional absolute finish deadline (< 0: none)
    # crash-recovery re-dispatch gate: admission never starts before
    # max(arrival, not_before).  A request requeued off a dead replica sets
    # this to the kill time so a lagging survivor cannot recompute the work
    # "before" the failure happened (arrival itself is untouched — TTFT keeps
    # its original basis).
    not_before: float = 0.0
    # real-execution engine state: tokenized prompt (np.ndarray int32) and
    # the emitted output token ids, filled in by ServingEngine.  Excluded
    # from __eq__: ndarray comparison would make Request equality raise.
    prompt: Optional[object] = dataclasses.field(default=None, compare=False)
    tokens: List[int] = dataclasses.field(default_factory=list, compare=False)
    # per-request sampling config (None: backend default) and the request's
    # PRNG *base* lane (np.ndarray uint32, set once at first admission and
    # never advanced — draw i folds the token position into it), which must
    # survive preemption/recompute and ride migrations.
    sampling: Optional[SamplingParams] = dataclasses.field(
        default=None, compare=False)
    rng_lane: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False)

    @property
    def done(self) -> bool:
        return self.finish >= 0

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival if self.first_token >= 0 else float("inf")


# -- stream events -------------------------------------------------------------
# Backends buffer these at their natural cadence (the real engines at decode-
# block granularity — never per token) and hand them out via
# ``Backend.drain_events`` — the observability surface for external
# consumers; ``serving.api`` handles read their request's token list
# directly.

@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """``n`` new tokens for stream ``rid``.  Real-execution backends carry
    the token ids; the discrete-event simulator emits counts only
    (``tokens=()``) — it models time and energy, not token values."""
    rid: int
    time: float
    tokens: Tuple[int, ...]
    n: int


@dataclasses.dataclass(frozen=True)
class StateEvent:
    """Stream ``rid`` entered ``state`` at backend time ``time``."""
    rid: int
    time: float
    state: RequestState


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Paper §4.2: Azure-style targets."""
    ttft_sm: float = 0.400     # s, short/medium prompts
    ttft_long: float = 2.000   # s, long prompts
    tbt_p95: float = 0.100     # s
    # margin factors (§5.3): scale the deadlines without re-engineering
    prefill_margin: float = 1.0
    decode_margin: float = 1.0

    def ttft_target(self, cls: str) -> float:
        base = self.ttft_long if cls == "L" else self.ttft_sm
        return base * self.prefill_margin

    @property
    def tbt_target(self) -> float:
        return self.tbt_p95 * self.decode_margin
