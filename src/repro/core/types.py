"""Shared serving-plane types."""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float            # s
    prompt_len: int
    output_len: int           # ground truth; unknown to the system a priori
    # runtime bookkeeping
    prefill_start: float = -1.0
    first_token: float = -1.0  # TTFT timestamp
    finish: float = -1.0
    tokens_emitted: int = 0
    cls: str = ""              # routing class ("SM" | "L")
    # real-execution engine state: tokenized prompt (np.ndarray int32) and
    # the emitted output token ids, filled in by ServingEngine.  Excluded
    # from __eq__: ndarray comparison would make Request equality raise.
    prompt: Optional[object] = dataclasses.field(default=None, compare=False)
    tokens: List[int] = dataclasses.field(default_factory=list, compare=False)

    @property
    def done(self) -> bool:
        return self.finish >= 0

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival if self.first_token >= 0 else float("inf")


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Paper §4.2: Azure-style targets."""
    ttft_sm: float = 0.400     # s, short/medium prompts
    ttft_long: float = 2.000   # s, long prompts
    tbt_p95: float = 0.100     # s
    # margin factors (§5.3): scale the deadlines without re-engineering
    prefill_margin: float = 1.0
    decode_margin: float = 1.0

    def ttft_target(self, cls: str) -> float:
        base = self.ttft_long if cls == "L" else self.ttft_sm
        return base * self.prefill_margin

    @property
    def tbt_target(self) -> float:
        return self.tbt_p95 * self.decode_margin
