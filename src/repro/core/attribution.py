"""Per-request energy attribution with an exact conservation invariant.

``EnergyLedger`` splits every replica's metered joules across the requests
resident during each accounting interval:

* **prefill** energy goes to the prefilling stream (whole prompts and
  Sarathi-style chunks alike — the chunk's request is the only resident),
* **decode-block** energy is shared across the step's active slots by
  tokens produced — every alive row emits exactly one token per fused
  step, so the per-step split is an equal ``e / n_alive`` share,
* **idle** energy stays an explicit *unattributed pool* per replica
  (nobody asked for it; hiding it inside request rows would fake the
  per-request numbers).

Conservation is a hard invariant, not a tolerance check, and it is held
with *dual bookkeeping*:

1. **Float mirrors** — for each (replica, phase) the ledger accumulates
   the exact same float values, in the exact same order, as the engine's
   own ``prefill_energy_j`` / ``decode_energy_j`` / ``idle_energy_j``
   counters (both start at 0.0 and see the identical ``+=`` sequence), so
   ``phase_total()`` is **bitwise equal** to the ``ReplicaReport`` energy
   fields — including across kills (billing stops at the kill snapshot),
   preemption + recompute (recompute work is billed again, to the same
   rid: that *is* the request's true cost), and the cluster's report-time
   makespan idle top-up (mirrored through ``set_idle_topup``).
2. **Exact rational partition** — every billed float is exactly a
   rational, so each interval's energy is split in ``fractions.Fraction``
   space where ``sum(shares) == Fraction(e)`` holds *identically* (float
   regrouping is non-associative; rationals are).  Per replica,
   ``attributed + idle pool == everything billed`` is therefore true by
   construction, and ``verify_conservation`` checks both layers.

Migrated streams carry their partial ledger in ``StreamHandoff`` via
``export_carry`` / ``adopt_carry``: when exporter and importer share one
ledger object (the cluster installs a single shared ledger on every
replica) the carry is a no-op; across *distinct* ledgers the request's
accumulated energy seeds the adopter's record without touching the
adopter's per-replica conservation (the joules were metered elsewhere).

``CounterfactualPricer`` prices the same intervals at the hardware's max
frequency using the replica's own fitted latency/power models — through a
**noiseless clone** of the plant (``noise_sigma=0``, its own RNG), because
the live plant's methods advance its RNG and calling them off the billing
path would perturb the run (the PR 7 step-identity invariant).  The
resulting ``energy_saved_j = e_at_fmax - e_metered`` is a model-based
estimate (floats, no exactness claim; near f_max the metered noise can
make single intervals slightly negative) of the paper's headline number,
live and per request.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["EnergyLedger", "CounterfactualPricer", "LedgerCarry",
           "verify_conservation"]

_PHASES = ("prefill", "decode", "idle")


@dataclasses.dataclass
class LedgerCarry:
    """A migrating stream's partial ledger (rides in ``StreamHandoff``).

    ``ledger`` is the *exporter's* ledger object: adoption into the same
    object is skipped (the record is already there — the cluster shares
    one ledger), adoption into a different ledger seeds the request's
    record without touching replica conservation."""
    ledger: "EnergyLedger"
    prefill: Fraction
    decode: Fraction
    saved_j: float
    tokens: int
    src: str


class _ReqRecord:
    __slots__ = ("rid", "prefill", "decode", "saved_j", "tokens",
                 "replicas", "carried_from")

    def __init__(self, rid: int):
        self.rid = rid
        self.prefill = Fraction(0)
        self.decode = Fraction(0)
        self.saved_j = 0.0
        self.tokens = 0
        self.replicas: List[str] = []
        self.carried_from: List[str] = []

    @property
    def energy(self) -> Fraction:
        return self.prefill + self.decode


class EnergyLedger:
    """Per-request energy attribution across one or many replicas.

    One instance may serve a whole cluster (that is how handoff carry
    stays a no-op); every record call names the billing replica."""

    def __init__(self):
        # float mirrors: same values, same order as the engine counters
        self._mirror: Dict[Tuple[str, str], float] = {}
        # exact rational layer
        self._frac_total: Dict[str, Fraction] = {}   # everything billed
        self._attr: Dict[str, Fraction] = {}         # request-attributed
        self._pool: Dict[str, Fraction] = {}         # idle pool
        self._saved: Dict[str, float] = {}           # counterfactual est.
        self._topup: Dict[str, float] = {}           # report-time idle
        self._req: Dict[int, _ReqRecord] = {}
        self.replicas: List[str] = []                # registration order

    # -- registration -------------------------------------------------------
    def register(self, replica: str) -> None:
        """Declare a replica so zero-energy replicas still verify/report."""
        if replica not in self._frac_total:
            self.replicas.append(replica)
            self._frac_total[replica] = Fraction(0)
            self._attr[replica] = Fraction(0)
            self._pool[replica] = Fraction(0)
            self._saved[replica] = 0.0
            for ph in _PHASES:
                self._mirror[(replica, ph)] = 0.0

    def _rec(self, rid: int) -> _ReqRecord:
        r = self._req.get(rid)
        if r is None:
            r = self._req[rid] = _ReqRecord(rid)
        return r

    # -- billing (called from the engines' existing accounting sites) -------
    def record_prefill(self, replica: str, rid: int, e_j: float, *,
                       tokens: int = 0, saved_j: float = 0.0) -> None:
        """Bill one prompt / one chunk of prefill: the prefilling stream is
        the interval's only resident, so it gets the whole amount."""
        self.register(replica)
        self._mirror[(replica, "prefill")] += e_j
        fe = Fraction(e_j)
        self._frac_total[replica] += fe
        self._attr[replica] += fe
        r = self._rec(rid)
        r.prefill += fe
        r.saved_j += saved_j
        r.tokens += tokens
        if replica not in r.replicas:
            r.replicas.append(replica)
        self._saved[replica] += saved_j

    def record_decode(self, replica: str, rids: Sequence[int], e_j: float,
                      *, saved_j: float = 0.0) -> None:
        """Bill one fused decode step shared by ``rids`` (the step's alive
        rows).  Each row produced exactly one token this step, so sharing
        by tokens produced is an equal split — done in Fraction space so
        the shares sum back to ``Fraction(e_j)`` identically."""
        self.register(replica)
        self._mirror[(replica, "decode")] += e_j
        fe = Fraction(e_j)
        self._frac_total[replica] += fe
        self._attr[replica] += fe
        n = len(rids)
        share = fe / n
        s_share = saved_j / n
        for rid in rids:
            r = self._rec(rid)
            r.decode += share
            r.saved_j += s_share
            r.tokens += 1
            if replica not in r.replicas:
                r.replicas.append(replica)
        self._saved[replica] += saved_j

    def record_idle(self, replica: str, e_j: float) -> None:
        """Bill an idle gap into the replica's unattributed pool."""
        self.register(replica)
        self._mirror[(replica, "idle")] += e_j
        fe = Fraction(e_j)
        self._frac_total[replica] += fe
        self._pool[replica] += fe

    def set_idle_topup(self, replica: str, e_j: float) -> None:
        """Idempotent report-time idle: the cluster bills alive replicas
        ``(makespan - vtime) * idle_power`` only when building a report
        (and may build several), so the ledger holds it in a slot that is
        overwritten, not accumulated.  It is pure idle-pool energy — the
        attribution identity is unaffected."""
        self.register(replica)
        self._topup[replica] = e_j

    # -- migration ----------------------------------------------------------
    def export_carry(self, replica: str, rid: int) -> LedgerCarry:
        """Snapshot a migrating request's accumulated attribution for its
        ``StreamHandoff``."""
        r = self._rec(rid)
        return LedgerCarry(ledger=self, prefill=r.prefill, decode=r.decode,
                           saved_j=r.saved_j, tokens=r.tokens, src=replica)

    def adopt_carry(self, carry: Optional[LedgerCarry], rid: int) -> None:
        """Merge a handed-off request's partial ledger.  No-op when the
        exporter billed into this very ledger (shared-ledger cluster);
        otherwise the amounts seed the request record only — replica
        conservation here is untouched because the joules were metered on
        the exporter."""
        if carry is None or carry.ledger is self:
            return
        r = self._rec(rid)
        r.prefill += carry.prefill
        r.decode += carry.decode
        r.saved_j += carry.saved_j
        r.tokens += carry.tokens
        r.carried_from.append(carry.src)

    # -- queries -------------------------------------------------------------
    def phase_total(self, replica: str, phase: str) -> float:
        """The float mirror for (replica, phase) — bitwise comparable with
        the ``ReplicaReport`` energy fields.  Idle includes the report-time
        makespan top-up exactly as the cluster row adds it (one ``+``)."""
        v = self._mirror.get((replica, phase), 0.0)
        if phase == "idle":
            t = self._topup.get(replica)
            if t is not None:
                v = v + t
        return v

    def request_energy_j(self, rid: int) -> float:
        r = self._req.get(rid)
        return float(r.energy) if r is not None else 0.0

    def request_saved_j(self, rid: int) -> float:
        r = self._req.get(rid)
        return r.saved_j if r is not None else 0.0

    def energy_by_rid(self) -> Dict[int, float]:
        return {rid: float(r.energy) for rid, r in self._req.items()}

    def saved_by_rid(self) -> Dict[int, float]:
        return {rid: r.saved_j for rid, r in self._req.items()}

    def replica_saved_j(self, replica: str) -> float:
        return self._saved.get(replica, 0.0)

    def saved_total_j(self) -> float:
        return sum(self._saved.values())

    def idle_pool_j(self, replica: Optional[str] = None) -> float:
        """Unattributed idle energy (pool + report-time top-up)."""
        if replica is not None:
            return float(self._pool.get(replica, Fraction(0))) \
                + self._topup.get(replica, 0.0)
        return sum(self.idle_pool_j(r) for r in self.replicas)

    def attributed_j(self, replica: Optional[str] = None) -> float:
        if replica is not None:
            return float(self._attr.get(replica, Fraction(0)))
        return sum(self.attributed_j(r) for r in self.replicas)

    def rows(self) -> List[Dict]:
        """Per-request attribution rows (the ``--attribution-out`` JSONL
        schema; see README "Energy attribution & alerts")."""
        out = []
        for rid in sorted(self._req):
            r = self._req[rid]
            out.append({
                "rid": rid,
                "prefill_j": float(r.prefill),
                "decode_j": float(r.decode),
                "energy_j": float(r.energy),
                "energy_saved_j": r.saved_j,
                "tokens": r.tokens,
                "replicas": list(r.replicas),
                "carried_from": list(r.carried_from),
            })
        return out

    # -- conservation --------------------------------------------------------
    def check_exact(self, replica: str) -> None:
        """The rational-layer identity: everything billed on ``replica``
        is either attributed to a request or in the idle pool — exactly."""
        total = self._frac_total.get(replica, Fraction(0))
        attr = self._attr.get(replica, Fraction(0))
        pool = self._pool.get(replica, Fraction(0))
        assert attr + pool == total, (
            f"{replica}: attributed {attr} + pool {pool} != billed {total} "
            f"(off by {float(total - attr - pool):.3e} J)")


def _field(row, name: str):
    if isinstance(row, dict):
        return row[name]
    return getattr(row, name)


def verify_conservation(ledger: EnergyLedger, rows) -> List[Dict]:
    """Check the full conservation invariant against backend report rows.

    ``rows`` is any iterable of mappings or objects exposing ``replica``
    (or ``name``), ``prefill_j``/``prefill_energy_j``, ``decode_j``/
    ``decode_energy_j`` and ``idle_j``/``idle_energy_j`` — duck-typed so
    ``core`` never imports a backend.  For every row this asserts

    1. the ledger's float mirrors equal the report fields **bitwise**, and
    2. the exact rational identity attributed + idle pool == billed.

    Returns per-replica summary dicts; raises AssertionError on the first
    violation.
    """
    def get(row, *names):
        for n in names:
            try:
                return _field(row, n)
            except (KeyError, AttributeError):
                continue
        raise KeyError(f"row {row!r} has none of {names}")

    out = []
    for row in rows:
        rep = get(row, "replica", "name")
        for phase, names in (("prefill", ("prefill_j", "prefill_energy_j")),
                             ("decode", ("decode_j", "decode_energy_j")),
                             ("idle", ("idle_j", "idle_energy_j"))):
            want = get(row, *names)
            got = ledger.phase_total(rep, phase)
            assert got == want, (
                f"{rep}/{phase}: ledger mirror {got!r} != report {want!r} "
                f"(diff {got - want:.3e} J — the mirrors must see the "
                f"identical float sequence as the engine counters)")
        ledger.check_exact(rep)
        out.append({
            "replica": rep,
            "attributed_j": ledger.attributed_j(rep),
            "idle_pool_j": ledger.idle_pool_j(rep),
            "energy_saved_j": ledger.replica_saved_j(rep),
        })
    return out


class CounterfactualPricer:
    """Price accounting intervals at the hardware's max frequency.

    Built on a **noiseless clone** of the replica's plant
    (``dataclasses.replace(plant, noise_sigma=0.0)`` — its own RNG, noise
    factor exactly 1.0): the live plant's latency/power methods advance
    its RNG, so pricing through them off the billing path would perturb
    the run and break the step-identity invariant.  ``saved = priced -
    metered`` is an estimate; the baseline deliberately excludes the
    metered sample's noise draw.
    """

    def __init__(self, plant):
        self._plant = dataclasses.replace(plant, noise_sigma=0.0)
        self.f_max = float(plant.hw.f_max)

    def prefill_j(self, n_tokens: int) -> float:
        t = self._plant.prefill_latency(n_tokens, self.f_max)
        return t * self._plant.prefill_power(n_tokens, self.f_max, t)

    def decode_j(self, batch: int, ctx: float) -> float:
        t = self._plant.decode_step_latency(batch, ctx, self.f_max)
        return t * self._plant.decode_power(batch, ctx, self.f_max, t)
