"""Adaptive prompt routing (paper §3.1).

Length-based partitioning: (n-1) threshold cut-offs divide traffic among n
prefill worker pools (n = 2 in the paper: short/medium "SM" up to ~1024
tokens, long "L" above).  Isolating long prompts removes head-of-line
blocking for the short-prompt majority.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from .types import Request


@dataclasses.dataclass(frozen=True)
class LengthRouter:
    thresholds: Sequence[int] = (1024,)
    class_names: Sequence[str] = ("SM", "L")

    def __post_init__(self):
        assert len(self.class_names) == len(self.thresholds) + 1
        assert list(self.thresholds) == sorted(self.thresholds)

    @property
    def num_classes(self) -> int:
        return len(self.class_names)

    def classify(self, prompt_len: int) -> int:
        for i, t in enumerate(self.thresholds):
            if prompt_len <= t:
                return i
        return len(self.thresholds)

    def route(self, req: Request) -> int:
        idx = self.classify(req.prompt_len)
        req.cls = self.class_names[idx]
        return idx


SINGLE_QUEUE = LengthRouter(thresholds=(), class_names=("SM",))


def make_router(enabled: bool = True) -> LengthRouter:
    """Paper default: 2 classes split at 1024 tokens; disabled -> one queue
    (the DefaultNV baseline routes everything to one pool)."""
    return LengthRouter() if enabled else SINGLE_QUEUE
