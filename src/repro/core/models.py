"""Controller-side fitted models (paper §3.2, Figures 7-8).

These are the *compact models* GreenLLM fits from short profiling traces.
They are deliberately simple (quadratic latency in prompt length, cubic
power in frequency, 1/f DVFS scaling) and are fitted against *measured*
samples of the plant — the controllers never read the plant's ground truth.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class QuadraticLatencyModel:
    """t_ref(L) = a L^2 + b L + c at a reference clock (Eq. 2);
    t(L, f) = t_ref(L) * f_ref / f (Eq. 3)."""
    a: float
    b: float
    c: float
    f_ref: float
    degree: int = 2

    @classmethod
    def fit(cls, lengths: Sequence[float], latencies: Sequence[float],
            f_ref: float, degree: int = 2) -> "QuadraticLatencyModel":
        L = np.asarray(lengths, np.float64)
        t = np.asarray(latencies, np.float64)
        if degree == 2:
            coef = np.polyfit(L, t, 2)
            a, b, c = coef
        else:  # attention-free archs (mamba2/recurrentgemma): linear fit
            b, c = np.polyfit(L, t, 1)
            a = 0.0
        return cls(float(a), float(b), float(c), f_ref, degree)

    def t_ref(self, L) -> np.ndarray:
        L = np.asarray(L, np.float64)
        return np.maximum(self.a * L * L + self.b * L + self.c, 1e-6)

    def predict(self, L, f) -> np.ndarray:
        return self.t_ref(L) * (self.f_ref / np.asarray(f, np.float64))

    def r2(self, lengths, latencies) -> float:
        t = np.asarray(latencies, np.float64)
        pred = self.t_ref(lengths)
        ss_res = float(np.sum((t - pred) ** 2))
        ss_tot = float(np.sum((t - t.mean()) ** 2)) + 1e-30
        return 1.0 - ss_res / ss_tot


@dataclasses.dataclass
class CubicPowerModel:
    """P(f) = k3 f^3 + k2 f^2 + k1 f + k0 (active), plus idle floor (Eq. 7).

    Frequencies are normalized by f_max before fitting for conditioning;
    ``predict`` takes MHz.
    """
    k: Tuple[float, float, float, float]
    f_max: float
    p_idle: float

    @classmethod
    def fit(cls, freqs: Sequence[float], powers: Sequence[float],
            f_max: float, p_idle: float) -> "CubicPowerModel":
        fn = np.asarray(freqs, np.float64) / f_max
        P = np.asarray(powers, np.float64)
        k = np.polyfit(fn, P, 3)
        return cls(tuple(float(x) for x in k), f_max, p_idle)

    def predict(self, f) -> np.ndarray:
        x = np.asarray(f, np.float64) / self.f_max
        k3, k2, k1, k0 = self.k
        return k3 * x ** 3 + k2 * x ** 2 + k1 * x + k0


@dataclasses.dataclass
class TPSFreqTable:
    """Offline decode profile: TPS bucket -> lowest-energy SLO-feasible clock
    (paper §3.3.1).  Buckets are the profiled TPS grid; adaptation (§3.3.3)
    may shift entries up/down at runtime.
    """
    tps_grid: np.ndarray       # ascending bucket upper edges
    freq_for: np.ndarray       # MHz per bucket
    f_step: float

    @classmethod
    def from_profile(cls, tps_levels: Sequence[float],
                     freqs: Sequence[float],
                     p95_tbt: np.ndarray,          # (n_tps, n_freq)
                     energy_per_token: np.ndarray,  # (n_tps, n_freq)
                     tbt_slo: float, f_step: float) -> "TPSFreqTable":
        tps_levels = np.asarray(tps_levels, np.float64)
        freqs = np.asarray(freqs, np.float64)
        chosen = []
        for i in range(len(tps_levels)):
            ok = p95_tbt[i] <= tbt_slo
            if not ok.any():
                chosen.append(freqs[-1])
                continue
            e = np.where(ok, energy_per_token[i], np.inf)
            chosen.append(freqs[int(np.argmin(e))])
        return cls(tps_levels, np.asarray(chosen), f_step)

    def bucket(self, tps: float) -> int:
        return int(np.searchsorted(self.tps_grid, tps, side="left")
                   .clip(0, len(self.tps_grid) - 1))

    def band(self, bucket: int, f_min: float, f_max: float):
        """(f_lo, f_mid, f_hi): the optimal clock plus its two neighbours."""
        f = float(self.freq_for[bucket])
        return (max(f - self.f_step, f_min), f, min(f + self.f_step, f_max))

    def shift(self, bucket: int, direction: int, f_min: float, f_max: float):
        self.freq_for[bucket] = float(
            np.clip(self.freq_for[bucket] + direction * self.f_step,
                    f_min, f_max))
