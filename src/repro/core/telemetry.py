"""Sliding-window telemetry: TPS estimation and P95 TBT tracking.

Empty-window semantics: aggregate queries that describe *samples* (``mean``
/ ``peak`` / ``p95`` / ``p99``) return ``nan`` when the trailing horizon
holds nothing — an empty window is "no data", which callers must not
confuse with "fast" (0.0 used to mean both; the decode controller's fine
loop would treat a freshly-evicted window as a latency of zero).  ``tps``
still returns 0.0: a window with no token arrivals *is* a rate of zero.
Use ``count(now)`` to distinguish explicitly.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Tuple

import numpy as np


class SlidingWindow:
    """Timestamped samples; query aggregates over a trailing horizon.

    Eviction is strict (``t < now - horizon``): a sample exactly at the
    horizon boundary is still in the window.  ``now`` is a high-water mark
    — out-of-order pushes are accepted (the sample counts) but never move
    time backwards, so a late sample older than the horizon is evicted as
    soon as eviction sweeps past it.
    """

    def __init__(self, horizon: float):
        self.horizon = horizon
        self._buf: Deque[Tuple[float, float]] = deque()
        self._hw = -np.inf          # high-water timestamp
        self._ooo = False           # an out-of-order sample is buried

    def push(self, t: float, value: float) -> None:
        if self._buf and t < self._buf[-1][0]:
            self._ooo = True
        self._buf.append((t, value))
        self._hw = max(self._hw, t)
        self._evict(self._hw)

    def _evict(self, now: float) -> None:
        cut = max(now, self._hw) - self.horizon
        buf = self._buf
        while buf and buf[0][0] < cut:
            buf.popleft()
        if self._ooo and buf:
            # an out-of-order push can bury an expired sample behind a
            # fresh one where the front-pop sweep never reaches it; the
            # engines' clocks are monotone, so this path costs nothing
            # unless a straggler actually arrived
            self._buf = deque((t, v) for t, v in buf if t >= cut)
            self._ooo = any(a[0] > b[0] for a, b in
                            zip(self._buf, list(self._buf)[1:]))

    def values(self, now: float) -> np.ndarray:
        self._evict(now)
        return np.asarray([v for _, v in self._buf], np.float64)

    def count(self, now: float) -> int:
        """Samples currently inside the horizon ending at ``now``."""
        self._evict(now)
        return len(self._buf)

    def __len__(self) -> int:
        return len(self._buf)


class TPSMeter(SlidingWindow):
    """Tokens-per-second over a trailing window (paper: 200 ms)."""

    def __init__(self, horizon: float = 0.200):
        super().__init__(horizon)

    def record_tokens(self, t: float, n: int) -> None:
        self.push(t, float(n))

    def tps(self, now: float) -> float:
        v = self.values(now)
        return float(v.sum() / self.horizon) if len(v) else 0.0


class OccupancyMeter(SlidingWindow):
    """KV-page pool occupancy over a trailing window (paged serving engine).

    Memory pressure is a controller input in later energy PRs: decode batch
    capacity — and therefore the reachable energy/token at a given frequency
    — is gated by pool headroom, so the dual-loop controller can trade clock
    against admission when ``mean()`` approaches 1."""

    def __init__(self, horizon: float = 1.0):
        super().__init__(horizon)

    def record(self, t: float, occupancy: float) -> None:
        self.push(t, occupancy)

    def mean(self, now: float) -> float:
        v = self.values(now)
        return float(v.mean()) if len(v) else float("nan")

    def peak(self, now: float) -> float:
        v = self.values(now)
        return float(v.max()) if len(v) else float("nan")


class TBTMeter(SlidingWindow):
    """Per-token latencies; P95 over a trailing window."""

    def __init__(self, horizon: float = 1.0):
        super().__init__(horizon)

    def record_tbt(self, t: float, tbt: float) -> None:
        self.push(t, tbt)

    def p95(self, now: float) -> float:
        v = self.values(now)
        return float(np.percentile(v, 95)) if len(v) else float("nan")

    def p99(self, now: float) -> float:
        v = self.values(now)
        return float(np.percentile(v, 99)) if len(v) else float("nan")
