"""Sliding-window telemetry: TPS estimation and P95 TBT tracking."""
from __future__ import annotations

from collections import deque
from typing import Deque, Tuple

import numpy as np


class SlidingWindow:
    """Timestamped samples; query aggregates over a trailing horizon."""

    def __init__(self, horizon: float):
        self.horizon = horizon
        self._buf: Deque[Tuple[float, float]] = deque()

    def push(self, t: float, value: float) -> None:
        self._buf.append((t, value))
        self._evict(t)

    def _evict(self, now: float) -> None:
        h = self.horizon
        while self._buf and self._buf[0][0] < now - h:
            self._buf.popleft()

    def values(self, now: float) -> np.ndarray:
        self._evict(now)
        return np.asarray([v for _, v in self._buf], np.float64)

    def __len__(self) -> int:
        return len(self._buf)


class TPSMeter(SlidingWindow):
    """Tokens-per-second over a trailing window (paper: 200 ms)."""

    def __init__(self, horizon: float = 0.200):
        super().__init__(horizon)

    def record_tokens(self, t: float, n: int) -> None:
        self.push(t, float(n))

    def tps(self, now: float) -> float:
        v = self.values(now)
        return float(v.sum() / self.horizon) if len(v) else 0.0


class OccupancyMeter(SlidingWindow):
    """KV-page pool occupancy over a trailing window (paged serving engine).

    Memory pressure is a controller input in later energy PRs: decode batch
    capacity — and therefore the reachable energy/token at a given frequency
    — is gated by pool headroom, so the dual-loop controller can trade clock
    against admission when ``mean()`` approaches 1."""

    def __init__(self, horizon: float = 1.0):
        super().__init__(horizon)

    def record(self, t: float, occupancy: float) -> None:
        self.push(t, occupancy)

    def mean(self, now: float) -> float:
        v = self.values(now)
        return float(v.mean()) if len(v) else 0.0

    def peak(self, now: float) -> float:
        v = self.values(now)
        return float(v.max()) if len(v) else 0.0


class TBTMeter(SlidingWindow):
    """Per-token latencies; P95 over a trailing window."""

    def __init__(self, horizon: float = 1.0):
        super().__init__(horizon)

    def record_tbt(self, t: float, tbt: float) -> None:
        self.push(t, tbt)

    def p95(self, now: float) -> float:
        v = self.values(now)
        return float(np.percentile(v, 95)) if len(v) else 0.0

    def p99(self, now: float) -> float:
        v = self.values(now)
        return float(np.percentile(v, 99)) if len(v) else 0.0
