from .hardware import HardwareProfile, A100_SXM4_40G, TPU_V5E, PROFILES
from .types import (Request, RequestState, SamplingParams, SLOConfig,
                    StateEvent, TokenEvent)
from .report import (ReplicaReport, RequestReport, ServingReport,
                     build_report, slo_pass_metrics)
from .models import QuadraticLatencyModel, CubicPowerModel, TPSFreqTable
from .router import LengthRouter, make_router, SINGLE_QUEUE
from .prefill_optimizer import PrefillOptimizer, deadline_from_queue
from .decode_controller import (DualLoopController, DecodeControllerConfig,
                                MaxFreqController, FixedFreqController)
from .telemetry import TPSMeter, TBTMeter, OccupancyMeter, SlidingWindow
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      parse_prometheus, quantile_from_buckets,
                      read_timeline_jsonl)
from .tracing import DvfsDecision, Span, Tracer, read_jsonl as read_trace_jsonl
from .attribution import (CounterfactualPricer, EnergyLedger, LedgerCarry,
                          verify_conservation)
from .alerts import Alert, AlertEngine, AlertRule
from . import controller_jax
