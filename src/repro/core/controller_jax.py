"""Pure-JAX dual-loop decode controller (paper §3.3 as a lax.scan step).

The Python ``DualLoopController`` is the serving-path implementation (it
runs off the accelerator's critical path, as the paper prescribes).  This
module provides the same control law as a *pure function over a state
pytree*, so fleets of controllers can be simulated on-device with
``jax.lax.scan`` / ``jax.vmap`` — used for batch what-if sweeps (thousands
of SLO/margin scenarios per second) and property-tested against the Python
controller for equivalence on identical telemetry.

Simplifications vs the Python class (documented, test-covered):
* telemetry arrives as per-fine-tick aggregates (tokens, p95 TBT estimate)
  instead of raw event streams — the sim/serving layers produce exactly
  these aggregates at 20 ms boundaries;
* the 6 s band-adaptation loop is not included (stateful table mutation);
  band selection + hysteresis + fine loop are bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hardware import HardwareProfile
from .models import TPSFreqTable


class CtlParams(NamedTuple):
    tps_grid: jax.Array       # (n_buckets,)
    freq_for: jax.Array       # (n_buckets,)
    f_min: jax.Array
    f_max: jax.Array
    f_step: jax.Array
    tbt_slo: jax.Array
    up_margin: jax.Array
    down_margin: jax.Array
    hysteresis: jax.Array     # int32
    ticks_per_coarse: jax.Array  # int32: fine ticks per coarse interval


class CtlState(NamedTuple):
    freq: jax.Array
    band_lo: jax.Array
    band_hi: jax.Array
    bucket: jax.Array         # int32, -1 = unset
    pending: jax.Array        # int32
    pending_count: jax.Array  # int32
    tick: jax.Array           # int32 fine-tick counter
    window_tokens: jax.Array  # tokens accumulated this coarse interval


def make_params(hw: HardwareProfile, table: TPSFreqTable,
                tbt_slo: float = 0.100, hysteresis: int = 3,
                fine_period: float = 0.020,
                coarse_period: float = 0.200) -> CtlParams:
    return CtlParams(
        tps_grid=jnp.asarray(table.tps_grid, jnp.float32),
        freq_for=jnp.asarray(table.freq_for, jnp.float32),
        f_min=jnp.asarray(hw.f_min, jnp.float32),
        f_max=jnp.asarray(hw.f_max, jnp.float32),
        f_step=jnp.asarray(hw.f_step, jnp.float32),
        tbt_slo=jnp.asarray(tbt_slo, jnp.float32),
        up_margin=jnp.asarray(1.0, jnp.float32),
        down_margin=jnp.asarray(0.65, jnp.float32),
        hysteresis=jnp.asarray(hysteresis, jnp.int32),
        ticks_per_coarse=jnp.asarray(round(coarse_period / fine_period),
                                     jnp.int32),
    )


def init_state(p: CtlParams) -> CtlState:
    return CtlState(
        freq=p.f_max,
        band_lo=p.f_max - p.f_step,
        band_hi=p.f_max,
        bucket=jnp.asarray(-1, jnp.int32),
        pending=jnp.asarray(-1, jnp.int32),
        pending_count=jnp.asarray(0, jnp.int32),
        tick=jnp.asarray(0, jnp.int32),
        window_tokens=jnp.asarray(0.0, jnp.float32),
    )


def _band(p: CtlParams, bucket):
    f = p.freq_for[bucket]
    return (jnp.maximum(f - p.f_step, p.f_min),
            jnp.minimum(f + p.f_step, p.f_max))


def controller_step(p: CtlParams, s: CtlState, tokens, p95_tbt
                    ) -> Tuple[CtlState, jax.Array]:
    """One 20 ms fine tick. tokens: emitted this tick; p95_tbt: current
    window P95 (s; 0 = no samples). Returns (state, frequency)."""
    window_tokens = s.window_tokens + tokens
    tick = s.tick + 1
    coarse_due = (tick % p.ticks_per_coarse) == 0

    # ---- coarse loop ------------------------------------------------------
    tps = window_tokens / (p.ticks_per_coarse.astype(jnp.float32) * 0.020)
    bucket_now = jnp.clip(
        jnp.searchsorted(p.tps_grid, tps, side="left"), 0,
        p.tps_grid.shape[0] - 1).astype(jnp.int32)

    def do_coarse(s):
        first = s.bucket < 0
        same = bucket_now == s.bucket
        pend_same = bucket_now == s.pending
        new_count = jnp.where(
            same, 0, jnp.where(pend_same, s.pending_count + 1, 1))
        commit = jnp.logical_and(~same, new_count >= p.hysteresis)
        adopt = jnp.logical_or(first, commit)
        bucket = jnp.where(adopt, bucket_now, s.bucket)
        lo, hi = _band(p, bucket)
        band_lo = jnp.where(adopt, lo, s.band_lo)
        band_hi = jnp.where(adopt, hi, s.band_hi)
        pending = jnp.where(jnp.logical_or(same, commit),
                            jnp.asarray(-1, jnp.int32), bucket_now)
        count = jnp.where(jnp.logical_or(same, commit), 0, new_count)
        return s._replace(bucket=bucket, band_lo=band_lo, band_hi=band_hi,
                          pending=pending, pending_count=count,
                          window_tokens=jnp.asarray(0.0, jnp.float32))

    s = jax.lax.cond(coarse_due, do_coarse,
                     lambda s: s._replace(window_tokens=window_tokens),
                     s._replace(window_tokens=window_tokens))

    # ---- fine loop ---------------------------------------------------------
    margin = p95_tbt / p.tbt_slo
    has_data = p95_tbt > 0.0
    up = jnp.logical_and(has_data, margin > p.up_margin)
    down = jnp.logical_and(has_data, margin < p.down_margin)
    freq = jnp.where(up, jnp.minimum(s.freq + p.f_step, s.band_hi),
                     jnp.where(down, jnp.maximum(s.freq - p.f_step, s.band_lo),
                               s.freq))
    freq = jnp.clip(freq, s.band_lo, s.band_hi)
    s = s._replace(freq=freq, tick=tick)
    return s, freq


def simulate(p: CtlParams, tokens_per_tick, p95_per_tick):
    """Run the controller over a telemetry trace with lax.scan.
    tokens_per_tick, p95_per_tick: (T,). Returns (final_state, freqs (T,))."""
    def body(s, xs):
        tok, tbt = xs
        s, f = controller_step(p, s, tok, tbt)
        return s, f

    return jax.lax.scan(body, init_state(p),
                        (jnp.asarray(tokens_per_tick, jnp.float32),
                         jnp.asarray(p95_per_tick, jnp.float32)))
