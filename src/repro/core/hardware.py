"""Hardware operating-point profiles (frequency ladder + roofline constants).

The GreenLLM control plane is hardware-agnostic: it needs a discrete ladder
of operating points, a latency model that scales ~1/f when compute-bound and
saturates when memory-bound, and a superlinear power curve.  We ship the
paper's plant (A100-SXM4-40G, NVML app-clock ladder 210..1410 MHz step 15)
and a TPU v5e-style profile (modeled ladder; TPUs expose no user clock API —
see DESIGN.md §2 for the adaptation argument).

Ground-truth *plant* power (used only by the simulator, never read by the
controllers, which must profile and fit):
    P_active(f, cu, mu) = p_idle
                        + p_dyn * [ (1-mem_frac) * cu * (f/f_max)^3
                                    + mem_frac * mu ]
where cu = compute utilization, mu = memory-bandwidth utilization in [0,1]
(memory clocks are pinned, so the HBM subsystem's power tracks activity, not
core frequency — this is what makes decode's energy knee sit lower).
"""
from __future__ import annotations

import dataclasses
import numpy as np


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    f_min: float            # MHz
    f_max: float            # MHz
    f_step: float           # MHz
    peak_flops: float       # FLOP/s at f_max (bf16)
    hbm_bw: float           # bytes/s (frequency-independent; mem clock pinned)
    ici_bw: float           # bytes/s per link (collectives)
    p_idle: float           # W
    p_dyn: float            # W of dynamic power at f_max, full compute util
    mem_frac: float = 0.30  # dynamic-power share tied to memory activity
    base_frac: float = 0.25  # active uncore/static share (weak f-dependence);
                             # this is what puts the prefill energy knee at
                             # ~70-80% f_max as measured in the paper (Fig 3a)
    kernel_overhead: float = 120e-6   # s per step launch/dispatch

    def ladder(self) -> np.ndarray:
        return np.arange(self.f_min, self.f_max + self.f_step / 2, self.f_step)

    def rel(self, f) -> np.ndarray:
        return np.asarray(f, dtype=np.float64) / self.f_max

    # ---- plant ground truth (simulator only) ----------------------------------
    def latency(self, flops: float, bytes_: float, f: float,
                mfu: float = 0.5, mbu: float = 0.75) -> float:
        """Roofline step latency at SM/core clock f.

        mfu/mbu: achievable fraction of peak compute / HBM bandwidth.
        The compute term scales with 1/f; the memory term does not.
        """
        t_comp = flops / (self.peak_flops * mfu * self.rel(f))
        t_mem = bytes_ / (self.hbm_bw * mbu)
        return float(np.maximum(t_comp, t_mem) + self.kernel_overhead)

    def power(self, flops: float, bytes_: float, f: float, latency: float,
              mfu: float = 0.5, mbu: float = 0.75) -> float:
        """Average active power over a step of the given latency."""
        if latency <= 0:
            return self.p_idle
        r = self.rel(f)
        cu = min(flops / (self.peak_flops * mfu * r) / latency, 1.0)
        mu = min(bytes_ / (self.hbm_bw * mbu) / latency, 1.0)
        comp_frac = 1.0 - self.mem_frac - self.base_frac
        dyn = self.p_dyn * (self.base_frac * (0.4 + 0.6 * r)
                            + comp_frac * cu * r ** 3
                            + self.mem_frac * mu * (0.3 + 0.7 * r))
        return float(self.p_idle + dyn)


A100_SXM4_40G = HardwareProfile(
    name="a100-sxm4-40g",
    f_min=210.0, f_max=1410.0, f_step=15.0,
    peak_flops=312e12, hbm_bw=1555e9, ici_bw=300e9,   # NVLink3 300 GB/s
    p_idle=62.0, p_dyn=338.0, mem_frac=0.3,
)

TPU_V5E = HardwareProfile(
    name="tpu-v5e",
    f_min=235.0, f_max=940.0, f_step=15.0,
    peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,     # per-link ICI
    p_idle=45.0, p_dyn=155.0, mem_frac=0.3,
)

PROFILES = {p.name: p for p in (A100_SXM4_40G, TPU_V5E)}
