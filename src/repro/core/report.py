"""Typed serving report shared by every backend.

``ServingReport`` replaces the string-keyed ``stats()`` dicts that used to
differ between ``ServingEngine``, ``ServingCluster`` and ``sim.replay`` (the
latter needed a ``metrics_from_cluster`` adapter just to compare runs): one
dataclass, one scoring definition (``slo_pass_metrics``), produced by
``Backend.report()`` on all three backends, so engine, cluster and simulator
replays of the same trace are comparable field-for-field by construction.

``slo_pass_metrics`` lives here (not in ``sim.replay``, which re-exports it)
because the serving package must not import the simulator's replay harness at
module scope — ``serving.engine`` already imports ``sim.plant``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from .types import Request, RequestState, SLOConfig


def slo_pass_metrics(requests: List[Request], tbt_records: Dict[int, list],
                     slo: SLOConfig,
                     class_names=("SM", "L")) -> Dict:
    """SLO scoring shared by the simulator, the real-execution engine, and
    the cluster (single definition = the parity guarantee): TTFT pass rate
    over requests that produced a first token, per-request p95-TBT pass
    rate, per-class p90 TTFT, and aggregate p95/p99 TBT (seconds)."""
    done = [r for r in requests if r.first_token >= 0]
    ttft_ok = sum(1 for r in done if r.ttft <= slo.ttft_target(r.cls))
    tbt_ok, total = 0, 0
    all_tbt: List[float] = []
    p95_by_rid: Dict[int, float] = {}   # reused by build_report's rows
    for r in done:
        tbts = tbt_records.get(r.rid, [])
        if not tbts:
            continue
        total += 1
        all_tbt.extend(tbts)
        p95_by_rid[r.rid] = float(np.percentile(tbts, 95))
        if p95_by_rid[r.rid] <= slo.tbt_target:
            tbt_ok += 1
    p90 = {}
    for cls in class_names:
        v = [r.ttft for r in done if r.cls == cls]
        if v:
            p90[cls] = float(np.percentile(v, 90))
    return {
        "ttft_pass": ttft_ok / max(len(done), 1),
        "tbt_pass": tbt_ok / max(total, 1),
        "p90_ttft": p90,
        "p95_tbt": float(np.percentile(all_tbt, 95)) if all_tbt else 0.0,
        "p99_tbt": float(np.percentile(all_tbt, 99)) if all_tbt else 0.0,
        "p95_tbt_by_rid": p95_by_rid,
    }


@dataclasses.dataclass(frozen=True)
class RequestReport:
    """Per-request SLO attainment row (times in seconds)."""
    rid: int
    cls: str
    state: RequestState
    arrival: float
    ttft: float                    # inf if no first token
    finish: float                  # -1 if not finished
    tokens_out: int
    ttft_ok: Optional[bool]        # None when no first token was produced
    p95_tbt: float                 # 0 when the stream recorded no TBTs
    tbt_ok: Optional[bool]         # None when no TBTs were recorded
    # None without a deadline or while unscorable (cancelled / in flight);
    # False for SHED rows — shedding *is* the deadline miss, recorded at
    # admission instead of discovered at finish
    deadline_ok: Optional[bool]
    # per-request energy attribution (core.attribution.EnergyLedger): the
    # request's share of metered joules across every replica that served
    # it, and the model-based estimate of joules saved vs running the same
    # intervals at max frequency.  0.0 when no ledger was installed.
    energy_j: float = 0.0
    energy_saved_j: float = 0.0


@dataclasses.dataclass(frozen=True)
class ReplicaReport:
    """Per-replica roll-up inside a cluster report (field names match the
    former ``ServingCluster.stats()['replicas']`` rows)."""
    name: str
    role: str
    vtime_s: float
    prefill_energy_j: float
    decode_energy_j: float
    idle_energy_j: float
    energy_j: float                # active + idle
    prefill_tokens: int
    decode_tokens: int
    exported: int
    imported: int
    preempted: int
    page_occupancy_peak: float
    freq_mhz: float
    # fault tolerance: a killed replica reports alive=False with its clock
    # frozen at killed_at — its energy stops accumulating at the kill, so
    # energy-per-request under a kill trace compares directly to a healthy
    # run (recompute work is billed on whichever survivor runs it)
    alive: bool = True
    killed_at: float = -1.0
    # counterfactual accounting (estimate): joules this replica saved vs
    # pricing its active intervals at max frequency (0 without a ledger)
    energy_saved_j: float = 0.0


@dataclasses.dataclass(frozen=True)
class ServingReport:
    """The one typed result of a serving run, whatever the data plane.

    Energy is split by phase (prefill / decode / idle up to the backend's
    makespan); SLO fields come from ``slo_pass_metrics`` — the same
    definition ``sim.replay.compute_metrics`` uses — and ``requests`` holds
    the per-request attainment rows."""
    backend: str                   # "engine" | "cluster" | "simulator"
    n_requests: int
    completed: int
    cancelled: int
    failed: int                    # given up by the system (watchdog / crash)
    shed: int                      # dropped by deadline-aware admission
    preempted: int
    migrated: int                  # cross-replica handoffs (0 off-cluster)
    prefill_energy_j: float
    decode_energy_j: float
    idle_energy_j: float
    prefill_tokens: int
    decode_tokens: int
    duration_s: float              # makespan (virtual time)
    ttft_pass: float
    tbt_pass: float
    p90_ttft_s: Mapping[str, float]
    p95_tbt_s: float
    p99_tbt_s: float
    page_occupancy_peak: float = 0.0
    requests: Tuple[RequestReport, ...] = ()
    replicas: Tuple[ReplicaReport, ...] = ()
    # cluster-wide counterfactual savings estimate vs max frequency
    # (0 without an attribution ledger installed)
    energy_saved_j: float = 0.0

    @property
    def total_energy_j(self) -> float:
        return self.prefill_energy_j + self.decode_energy_j \
            + self.idle_energy_j

    @property
    def throughput_tok_s(self) -> float:
        return self.decode_tokens / max(self.duration_s, 1e-9)

    def summary(self) -> str:
        """Human-readable one-screen digest (CLI / example output)."""
        e_line = (f"energy: prefill={self.prefill_energy_j / 1e3:.2f}kJ  "
                  f"decode={self.decode_energy_j / 1e3:.2f}kJ  "
                  f"idle={self.idle_energy_j / 1e3:.2f}kJ  "
                  f"total={self.total_energy_j / 1e3:.2f}kJ")
        if self.energy_saved_j:
            e_line += (f"  saved_vs_fmax={self.energy_saved_j / 1e3:.2f}kJ "
                       f"({100 * self.energy_saved_j / max(self.total_energy_j + self.energy_saved_j, 1e-12):.1f}%)")
        lines = [
            f"backend={self.backend}  requests={self.n_requests}  "
            f"completed={self.completed}  cancelled={self.cancelled}  "
            f"failed={self.failed}  shed={self.shed}  "
            f"preempted={self.preempted}  migrated={self.migrated}",
            f"duration={self.duration_s:.2f}s  "
            f"throughput={self.throughput_tok_s:.0f} tok/s",
            e_line,
            f"SLO: TTFT pass={self.ttft_pass * 100:.0f}%  "
            f"TBT pass={self.tbt_pass * 100:.0f}%  "
            f"p95 TBT={self.p95_tbt_s * 1e3:.1f}ms",
        ]
        if self.p90_ttft_s:
            per = "  ".join(f"{c}={v * 1e3:.0f}ms"
                            for c, v in sorted(self.p90_ttft_s.items()))
            lines.append(f"p90 TTFT: {per}")
        return "\n".join(lines)


def build_report(*, backend: str, requests: List[Request],
                 tbt_records: Dict[int, list], slo: SLOConfig,
                 class_names, prefill_energy_j: float,
                 decode_energy_j: float, idle_energy_j: float,
                 prefill_tokens: int, decode_tokens: int, duration_s: float,
                 preempted: int = 0, migrated: int = 0,
                 page_occupancy_peak: float = 0.0,
                 replicas: Tuple[ReplicaReport, ...] = (),
                 energy_by_rid: Optional[Dict[int, float]] = None,
                 saved_by_rid: Optional[Dict[int, float]] = None,
                 energy_saved_j: float = 0.0) -> ServingReport:
    """Assemble a ``ServingReport``: aggregate SLO scoring via
    ``slo_pass_metrics`` plus per-request attainment rows.  The optional
    ``energy_by_rid`` / ``saved_by_rid`` maps (from an attribution ledger)
    fill the per-request energy fields."""
    m = slo_pass_metrics(requests, tbt_records, slo, class_names)
    e_rid = energy_by_rid or {}
    s_rid = saved_by_rid or {}
    rows = []
    for r in requests:
        tbts = tbt_records.get(r.rid, [])
        p95 = m["p95_tbt_by_rid"].get(r.rid)
        if p95 is None:     # no first token recorded -> scored nowhere
            p95 = float(np.percentile(tbts, 95)) if tbts else 0.0
        rows.append(RequestReport(
            rid=r.rid, cls=r.cls, state=r.state, arrival=r.arrival,
            ttft=r.ttft, finish=r.finish, tokens_out=r.tokens_emitted,
            # None (not False) without a first token: the aggregate
            # ttft_pass excludes such requests, and row-level consumers
            # recomputing the rate from these rows must agree with it
            ttft_ok=(r.ttft <= slo.ttft_target(r.cls))
            if r.first_token >= 0 else None,
            p95_tbt=p95,
            tbt_ok=(p95 <= slo.tbt_target) if tbts else None,
            # scorable once finished — or shed: a SHED request *is* a
            # deadline miss, recorded at admission.  Cancelled / in-flight
            # rows are None, not misses.
            deadline_ok=False if r.state is RequestState.SHED
            else (r.finish <= r.deadline)
            if r.deadline >= 0 and r.finish >= 0 else None,
            energy_j=e_rid.get(r.rid, 0.0),
            energy_saved_j=s_rid.get(r.rid, 0.0)))
    return ServingReport(
        backend=backend,
        n_requests=len(requests),
        completed=sum(1 for r in requests if r.finish >= 0),
        cancelled=sum(1 for r in requests
                      if r.state is RequestState.CANCELLED),
        failed=sum(1 for r in requests
                   if r.state is RequestState.FAILED),
        shed=sum(1 for r in requests if r.state is RequestState.SHED),
        preempted=preempted, migrated=migrated,
        prefill_energy_j=prefill_energy_j,
        decode_energy_j=decode_energy_j,
        idle_energy_j=idle_energy_j,
        prefill_tokens=prefill_tokens, decode_tokens=decode_tokens,
        duration_s=duration_s,
        ttft_pass=m["ttft_pass"], tbt_pass=m["tbt_pass"],
        p90_ttft_s=dict(m["p90_ttft"]),
        p95_tbt_s=m["p95_tbt"], p99_tbt_s=m["p99_tbt"],
        page_occupancy_peak=page_occupancy_peak,
        requests=tuple(rows), replicas=replicas,
        energy_saved_j=energy_saved_j)
