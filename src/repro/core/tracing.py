"""Request-lifecycle tracing + DVFS decision logs on the virtual clock.

Two record types, one ring-buffered collector:

* ``Span`` — one interval (or instant, ``end == start``) in a request's
  life: ``submit → queue → prefill`` chunks ``→ decode_block``s ``→
  handoff → finish | cancel | shed | fail``, plus replica-level events
  (faults, preemptions).  ``rid`` is -1 for spans not tied to one request
  (e.g. a decode block serving a whole batch, a replica kill).
* ``DvfsDecision`` — one controller action: every ``DualLoopController``
  tick and every ``PrefillOptimizer`` solve records its *inputs* (TPS, p95
  TBT, occupancy, queue state), the chosen frequency, and a **reason
  code**, so "why did the clock move?" is answerable from the log alone.

Timestamps are virtual-clock seconds (the engines' energy/SLO clock), so
traces are deterministic and replayable.  The collector is a bounded
``deque`` — a long-lived server never grows without bound; ``dropped``
counts evictions.  Writers: Chrome trace-event JSON (load in
``chrome://tracing`` / Perfetto; replicas become processes, requests
become threads) and a JSONL form that round-trips via ``read_jsonl``.

Like the metrics registry, tracing rides existing host-sync points: every
emission site is guarded by ``tracer is not None`` and records host floats
the engine already had — no device syncs, zero overhead when off.
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional


@dataclass(frozen=True)
class Span:
    """One lifecycle interval on the virtual clock (instant if end==start)."""
    name: str
    rid: int
    start: float
    end: float
    replica: str = ""
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclass(frozen=True)
class DvfsDecision:
    """One controller action: chosen frequency + reason + inputs."""
    t: float
    replica: str
    phase: str            # "prefill" | "decode"
    freq_mhz: float
    reason: str           # stable reason code, e.g. "tbt_pressure"
    inputs: Dict[str, Any] = field(default_factory=dict)


class Tracer:
    """Ring-buffered span + DVFS-decision collector.

    ``capacity`` bounds each ring independently; the oldest records are
    evicted first and counted in ``dropped_spans`` / ``dropped_decisions``.
    """

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self._spans: deque = deque(maxlen=self.capacity)
        self._decisions: deque = deque(maxlen=self.capacity)
        self.dropped_spans = 0
        self.dropped_decisions = 0

    # -- recording (hot path: one dataclass + one deque append) ----------------
    def span(self, name: str, rid: int, start: float, end: float,
             replica: str = "", **attrs) -> None:
        if len(self._spans) == self.capacity:
            self.dropped_spans += 1
        self._spans.append(Span(name, rid, float(start), float(end),
                                replica, attrs))

    def instant(self, name: str, rid: int, t: float,
                replica: str = "", **attrs) -> None:
        self.span(name, rid, t, t, replica, **attrs)

    def decision(self, t: float, replica: str, phase: str, freq_mhz: float,
                 reason: str, **inputs) -> None:
        if len(self._decisions) == self.capacity:
            self.dropped_decisions += 1
        self._decisions.append(DvfsDecision(float(t), replica, phase,
                                            float(freq_mhz), reason, inputs))

    def bind(self, replica: str):
        """A ``decision``-shaped callback with the replica pinned — what a
        controller that doesn't know its replica name gets installed."""
        def _cb(t, phase, freq_mhz, reason, **inputs):
            self.decision(t, replica, phase, freq_mhz, reason, **inputs)
        return _cb

    # -- querying ---------------------------------------------------------------
    def spans(self, name: Optional[str] = None,
              rid: Optional[int] = None,
              replica: Optional[str] = None) -> List[Span]:
        out = []
        for s in self._spans:
            if name is not None and s.name != name:
                continue
            if rid is not None and s.rid != rid:
                continue
            if replica is not None and s.replica != replica:
                continue
            out.append(s)
        return out

    def decisions(self, replica: Optional[str] = None,
                  phase: Optional[str] = None) -> List[DvfsDecision]:
        return [d for d in self._decisions
                if (replica is None or d.replica == replica)
                and (phase is None or d.phase == phase)]

    def decision_at(self, t: float, replica: str,
                    phase: str = "decode") -> Optional[DvfsDecision]:
        """The latest decision at or before ``t`` for one replica/phase —
        'why was the clock what it was at this instant?'."""
        best = None
        for d in self._decisions:
            if d.replica == replica and d.phase == phase and d.t <= t:
                if best is None or d.t >= best.t:
                    best = d
        return best

    def __len__(self) -> int:
        return len(self._spans)

    # -- export -----------------------------------------------------------------
    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON: one process per replica, one thread per
        request (rid -1 → thread 0), virtual seconds as microseconds."""
        pids: Dict[str, int] = {}
        events: List[Dict[str, Any]] = []
        for s in self._spans:
            pid = pids.setdefault(s.replica or "node", len(pids) + 1)
            ev = {"name": s.name, "ph": "X", "pid": pid,
                  "tid": s.rid + 1,          # rid -1 → tid 0
                  "ts": round(s.start * 1e6, 3),
                  "dur": round(s.duration * 1e6, 3),
                  "args": dict(s.attrs, rid=s.rid)}
            if s.end == s.start:
                ev["ph"] = "i"
                ev["s"] = "t"                # thread-scoped instant
                del ev["dur"]
            events.append(ev)
        for d in self._decisions:
            pid = pids.setdefault(d.replica or "node", len(pids) + 1)
            events.append({"name": f"dvfs:{d.reason}", "ph": "i", "s": "p",
                           "pid": pid, "tid": 0,
                           "ts": round(d.t * 1e6, 3),
                           "args": dict(d.inputs, phase=d.phase,
                                        freq_mhz=d.freq_mhz)})
        meta = [{"name": "process_name", "ph": "M", "pid": pid,
                 "args": {"name": name}} for name, pid in pids.items()]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_chrome_trace(), fh)

    def write_jsonl(self, path: str) -> int:
        """One record per line: ``{"kind": "span"|"dvfs", ...}``.  Returns
        the number of lines written; ``read_jsonl`` round-trips it."""
        n = 0
        with open(path, "w") as fh:
            for s in self._spans:
                fh.write(json.dumps({
                    "kind": "span", "name": s.name, "rid": s.rid,
                    "start": s.start, "end": s.end, "replica": s.replica,
                    "attrs": s.attrs}) + "\n")
                n += 1
            for d in self._decisions:
                fh.write(json.dumps({
                    "kind": "dvfs", "t": d.t, "replica": d.replica,
                    "phase": d.phase, "freq_mhz": d.freq_mhz,
                    "reason": d.reason, "inputs": d.inputs}) + "\n")
                n += 1
        return n


def read_jsonl(path: str) -> "Tracer":
    """Rebuild a ``Tracer`` from ``write_jsonl`` output (validating kinds)."""
    tr = Tracer()
    with open(path) as fh:
        for lineno, line in enumerate(fh, 1):
            if not line.strip():
                continue
            doc = json.loads(line)
            kind = doc.get("kind")
            if kind == "span":
                tr.span(doc["name"], int(doc["rid"]), doc["start"],
                        doc["end"], doc.get("replica", ""),
                        **doc.get("attrs", {}))
            elif kind == "dvfs":
                tr.decision(doc["t"], doc["replica"], doc["phase"],
                            doc["freq_mhz"], doc["reason"],
                            **doc.get("inputs", {}))
            else:
                raise ValueError(f"line {lineno}: unknown record kind "
                                 f"{kind!r}")
    return tr
