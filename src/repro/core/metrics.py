"""Production metrics: a labelled counter/gauge/histogram registry with
Prometheus text exposition and a JSONL snapshot timeline.

GreenLLM's headline claim — energy saved at bounded SLO damage — is a
*telemetry* claim, so the serving planes publish first-class metrics instead
of only post-hoc ``ServingReport``s: per-replica SM frequency, per-phase
energy, page-pool occupancy, queue depths, lifecycle counters, TTFT/TBT
histograms.  The registry is deliberately small and dependency-free:

* ``Counter`` / ``Gauge`` / ``Histogram`` families with label names; children
  are created lazily per label-value tuple and cached, so the hot path is a
  dict lookup + float add.
* ``render_prometheus()`` emits the text exposition format (``# HELP`` /
  ``# TYPE`` + one line per series; histograms as ``_bucket``/``_sum``/
  ``_count`` with cumulative ``le`` buckets).  ``parse_prometheus`` is the
  matching validator used by CI and tests.
* ``record_snapshot(t)`` appends a flat ``{series: value}`` dict to an
  in-memory timeline keyed by *virtual-clock* time; ``query(t)`` returns the
  last snapshot at or before ``t``, which is what makes frequency / energy /
  occupancy / tail-TBT queryable at any instant of a replayed trace.
  ``write_timeline_jsonl`` / ``read_timeline_jsonl`` round-trip it.

Emission rides the backends' existing block cadence (see
``serving.engine``): metric updates are host-side float math on values the
engine already computed — publishing adds **no device syncs**, and a backend
with no registry installed skips every site (the ``events_on`` pattern).

Metric *names* are a stable API (ROADMAP PR 7 invariants): renaming a series
is a breaking change to every dashboard built on it.
"""
from __future__ import annotations

import bisect
import json
import math
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple


def _format_value(v: float) -> str:
    if v != v:                  # NaN first: int(nan) raises
        return "NaN"
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape_label(v: str) -> str:
    """Text-exposition label-value escaping: backslash, double quote and
    newline (in that order — escaping the escape char first)."""
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


# flat() runs at block cadence and rebuilds the key of every live series
# each snapshot; label escaping made that measurably hot, so keys are
# memoized (sound: children are immutable per label-value tuple, and the
# cache is bounded by series cardinality)
_KEY_CACHE: Dict[Tuple[str, Tuple[str, ...], Tuple[str, ...]], str] = {}


def _series_key(name: str, labelnames: Sequence[str],
                labelvalues: Sequence[str]) -> str:
    if not labelnames:
        return name
    ck = (name, tuple(labelnames), tuple(labelvalues))
    key = _KEY_CACHE.get(ck)
    if key is None:
        inner = ",".join(f'{k}="{_escape_label(v)}"'
                         for k, v in zip(labelnames, labelvalues))
        key = _KEY_CACHE[ck] = f"{name}{{{inner}}}"
    return key


class _Family:
    """Shared plumbing of a metric family: label handling + child cache."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: Dict[Tuple[str, ...], object] = {}

    def _child(self, labelvalues: Tuple[str, ...]):
        c = self._children.get(labelvalues)
        if c is None:
            if len(labelvalues) != len(self.labelnames):
                raise ValueError(
                    f"{self.name}: expected labels {self.labelnames}, "
                    f"got {labelvalues}")
            c = self._make_child()
            self._children[labelvalues] = c
        return c

    def labels(self, *labelvalues, **labelkv):
        """Bind a child for one label-value combination (cached).  Hot
        paths should bind once and hold the child."""
        if labelkv:
            labelvalues = tuple(str(labelkv[k]) for k in self.labelnames)
        else:
            labelvalues = tuple(str(v) for v in labelvalues)
        return self._child(labelvalues)

    def _make_child(self):  # pragma: no cover - overridden
        raise NotImplementedError

    def samples(self) -> Iterable[Tuple[str, Tuple[str, ...], float]]:
        """(suffix, labelvalue-extension, value) triples for exposition."""
        raise NotImplementedError


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up (inc {amount})")
        self.value += amount


class Counter(_Family):
    """Monotone cumulative count (requests, joules, tokens, faults)."""

    kind = "counter"

    def _make_child(self):
        return _CounterChild()

    def inc(self, amount: float = 1.0, **labels) -> None:
        self.labels(**labels).inc(amount) if self.labelnames \
            else self._child(()).inc(amount)

    def samples(self):
        for lv, c in self._children.items():
            yield "", lv, c.value


class _GaugeChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Gauge(_Family):
    """Point-in-time value (frequency, occupancy, queue depth)."""

    kind = "gauge"

    def _make_child(self):
        return _GaugeChild()

    def set(self, value: float, **labels) -> None:
        (self.labels(**labels) if self.labelnames
         else self._child(())).set(value)

    def samples(self):
        for lv, c in self._children.items():
            yield "", lv, c.value


class _HistogramChild:
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Tuple[float, ...]):
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)      # last = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float, n: int = 1) -> None:
        """Record ``value`` ``n`` times (``n`` > 1 for per-step TBTs shared
        by a whole decode batch — exact, without n python calls)."""
        i = bisect.bisect_left(self.buckets, value)
        self.counts[i] += n
        self.sum += value * n
        self.count += n

    def quantile(self, q: float) -> float:
        """Bucket-quantile estimate (p50/p95/p99) from this child's
        cumulative counts — see ``quantile_from_buckets``."""
        pairs: List[Tuple[float, float]] = []
        cum = 0
        for b, n in zip(self.buckets, self.counts):
            cum += n
            pairs.append((b, float(cum)))
        pairs.append((math.inf, float(self.count)))
        return quantile_from_buckets(pairs, q)


def quantile_from_buckets(pairs: Sequence[Tuple[float, float]],
                          q: float) -> float:
    """Prometheus-style ``histogram_quantile`` over cumulative buckets:
    ``pairs`` is ``(le, cumulative_count)`` including the ``+Inf`` bucket.
    Linear interpolation inside the bucket containing rank ``q * count``;
    a rank landing in the ``+Inf`` bucket clamps to the highest finite
    bound (there is no upper edge to interpolate toward).  Returns NaN on
    an empty histogram.  This is the one shared implementation for alert
    rules, the dashboard, and ad-hoc analysis — don't re-derive it.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    pairs = sorted(pairs, key=lambda p: p[0])
    if not pairs:
        return math.nan
    total = pairs[-1][1]
    if total <= 0:
        return math.nan
    rank = q * total
    prev_le, prev_cum = 0.0, 0.0        # implicit lower edge of bucket 0
    for le, cum in pairs:
        if cum >= rank:
            if math.isinf(le):
                return prev_le          # clamp: highest finite bound
            if cum == prev_cum:
                return le
            return prev_le + (le - prev_le) * (rank - prev_cum) \
                / (cum - prev_cum)
        prev_le, prev_cum = le, cum
    return prev_le


DEFAULT_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0)


class Histogram(_Family):
    """Cumulative-bucket distribution (TTFT, TBT)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")

    def _make_child(self):
        return _HistogramChild(self.buckets)

    def observe(self, value: float, n: int = 1, **labels) -> None:
        (self.labels(**labels) if self.labelnames
         else self._child(())).observe(value, n)

    def quantile(self, q: float, **labels) -> float:
        """Bucket-quantile estimate for one child (NaN when empty)."""
        return (self.labels(**labels) if self.labelnames
                else self._child(())).quantile(q)

    def samples(self):
        for lv, c in self._children.items():
            cum = 0
            for b, n in zip(self.buckets, c.counts):
                cum += n
                yield "_bucket", lv + (("le", _format_value(b)),), float(cum)
            yield "_bucket", lv + (("le", "+Inf"),), float(c.count)
            yield "_sum", lv, c.sum
            yield "_count", lv, float(c.count)


class MetricsRegistry:
    """One namespace of metric families plus the snapshot timeline.

    ``snapshot_min_dt`` throttles ``record_snapshot``: a backend may call it
    every block, and the registry keeps at most one snapshot per
    ``snapshot_min_dt`` virtual seconds (0 keeps everything).
    """

    def __init__(self, snapshot_min_dt: float = 0.0):
        self._families: Dict[str, _Family] = {}
        self.snapshot_min_dt = float(snapshot_min_dt)
        self.timeline: List[Tuple[float, Dict[str, float]]] = []

    # -- family construction (get-or-create, type-checked) ---------------------
    def _get(self, cls, name: str, help: str, labelnames, **kw):
        fam = self._families.get(name)
        if fam is None:
            fam = cls(name, help, labelnames, **kw)
            self._families[name] = fam
            return fam
        if not isinstance(fam, cls) or fam.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} re-registered with a different type or "
                f"label set ({fam.kind}{fam.labelnames} vs "
                f"{cls.kind}{tuple(labelnames)})")
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labelnames, buckets=buckets)

    # -- export -----------------------------------------------------------------
    def flat(self) -> Dict[str, float]:
        """Every series as ``name{label="v",...} -> value`` (histograms
        expanded to ``_bucket``/``_sum``/``_count``)."""
        out: Dict[str, float] = {}
        for fam in self._families.values():
            base = list(fam.labelnames)
            for suffix, lv, value in fam.samples():
                if suffix == "_bucket":
                    names = base + [lv[-1][0]]
                    values = list(lv[:-1]) + [lv[-1][1]]
                else:
                    names, values = base, list(lv)
                out[_series_key(fam.name + suffix, names, values)] = value
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4)."""
        lines: List[str] = []
        for name in sorted(self._families):
            fam = self._families[name]
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            base = list(fam.labelnames)
            for suffix, lv, value in fam.samples():
                if suffix == "_bucket":
                    names = base + [lv[-1][0]]
                    values = list(lv[:-1]) + [lv[-1][1]]
                else:
                    names, values = base, list(lv)
                key = _series_key(fam.name + suffix, names, values)
                lines.append(f"{key} {_format_value(value)}")
        return "\n".join(lines) + "\n"

    # -- the timeline -----------------------------------------------------------
    def record_snapshot(self, t: float) -> bool:
        """Append the current flat view at virtual time ``t`` (throttled by
        ``snapshot_min_dt``; a later call at the same ``t`` replaces the
        snapshot so one instant has one state).  Returns True if recorded."""
        if self.timeline:
            last_t = self.timeline[-1][0]
            if t < last_t:
                return False             # clocks never move backwards
            if t == last_t:
                self.timeline[-1] = (t, self.flat())
                return True
            if self.snapshot_min_dt and t - last_t < self.snapshot_min_dt:
                return False
        self.timeline.append((float(t), self.flat()))
        return True

    def query(self, t: float) -> Optional[Dict[str, float]]:
        """The metric state at virtual instant ``t``: the last snapshot at
        or before ``t`` (None before the first snapshot)."""
        times = [s[0] for s in self.timeline]
        i = bisect.bisect_right(times, t)
        return None if i == 0 else self.timeline[i - 1][1]

    def series(self, key: str) -> List[Tuple[float, float]]:
        """One series' (t, value) trajectory across the timeline (missing
        snapshots skipped) — e.g. a replica's frequency over the run."""
        return [(t, snap[key]) for t, snap in self.timeline if key in snap]

    def write_timeline_jsonl(self, path: str) -> int:
        """One JSON object per snapshot: ``{"t": .., "metrics": {...}}``.
        Returns the number of lines written."""
        with open(path, "w") as fh:
            for t, snap in self.timeline:
                fh.write(json.dumps({"t": t, "metrics": snap}) + "\n")
        return len(self.timeline)


def read_timeline_jsonl(path: str) -> List[Tuple[float, Dict[str, float]]]:
    out = []
    with open(path) as fh:
        for line in fh:
            if line.strip():
                doc = json.loads(line)
                out.append((float(doc["t"]), dict(doc["metrics"])))
    return out


def _parse_series(line: str, lineno: int) -> Tuple[str, str]:
    """Split one sample line into (series key, raw value), scanning the
    label block character-by-character: quoted label values may contain
    commas, spaces, braces and the escapes ``\\\\``, ``\\"``, ``\\n``, so
    naive ``split(",")`` / ``rpartition(" ")`` slicing is wrong on hostile
    labels.  The key keeps the escaped text verbatim — exactly what
    ``flat()`` uses — so exposition round-trips key-for-key."""
    n = len(line)
    i = 0
    while i < n and (line[i].isalnum() or line[i] in "_:"):
        i += 1
    name = line[:i]
    if not name or not (name[0].isalpha() or name[0] == "_"):
        raise ValueError(f"line {lineno}: bad metric name in {line!r}")
    if i < n and line[i] == "{":
        i += 1
        while True:
            if i >= n:
                raise ValueError(f"line {lineno}: unbalanced labels: {line!r}")
            if line[i] == "}":
                i += 1
                break
            j = i
            while j < n and (line[j].isalnum() or line[j] == "_"):
                j += 1
            if j == i or line[i].isdigit() or j >= n or line[j] != "=":
                raise ValueError(
                    f"line {lineno}: bad label name at col {i}: {line!r}")
            i = j + 1
            if i >= n or line[i] != '"':
                raise ValueError(
                    f"line {lineno}: unquoted label value: {line!r}")
            i += 1
            while i < n and line[i] != '"':
                if line[i] == "\\":
                    if i + 1 >= n or line[i + 1] not in ('\\', '"', 'n'):
                        raise ValueError(
                            f"line {lineno}: bad escape at col {i}: {line!r}")
                    i += 1
                i += 1
            if i >= n:
                raise ValueError(
                    f"line {lineno}: unterminated label value: {line!r}")
            i += 1                       # closing quote
            if i < n and line[i] == ",":
                i += 1                   # separator (or legal trailing comma)
            elif i >= n or line[i] != "}":
                raise ValueError(
                    f"line {lineno}: expected ',' or '}}' at col {i}: "
                    f"{line!r}")
    key = line[:i]
    rest = line[i:]
    if not rest or rest[0] not in " \t":
        raise ValueError(f"line {lineno}: no value: {line!r}")
    fields = rest.split()
    if not fields:
        raise ValueError(f"line {lineno}: no value: {line!r}")
    return key, fields[0]                # fields[1], if any, is a timestamp


def parse_prometheus(text: str) -> Dict[str, float]:
    """Validating parser for the text exposition format: returns
    ``{series_key: value}`` and raises ``ValueError`` on malformed lines.
    Used by CI to check that what ``render_prometheus`` wrote is readable;
    round-trips hostile label values (quotes, commas, newlines, braces,
    backslashes) and legal non-finite samples (``NaN``, ``+Inf``)."""
    out: Dict[str, float] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        key, raw = _parse_series(line, lineno)
        try:
            out[key] = float(raw)
        except ValueError as e:
            raise ValueError(f"line {lineno}: bad value {raw!r}") from e
    return out
