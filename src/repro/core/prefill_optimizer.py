"""Queueing-aware prefill frequency optimizer (paper §3.2, Eq. 4-14).

Given the pending prefill jobs of a class (their predicted reference
latencies), an SLO interval D, the fitted cubic power model and the idle
power, pick the ladder frequency minimizing

    E_total(f) = P(f) * busy(f) + P_idle * [D - busy(f)],
    busy(f)    = (f_ref / f) * T_ref,           s.t.  busy(f) <= D.

If no ladder point is feasible the optimizer returns f_max (protect the SLO,
paper §5.1.1 "collapses near saturation").
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from .hardware import HardwareProfile
from .models import CubicPowerModel, QuadraticLatencyModel


@dataclasses.dataclass
class PrefillOptimizer:
    latency_model: QuadraticLatencyModel
    power_model: CubicPowerModel
    hw: HardwareProfile
    p_idle: float

    def busy_time(self, lengths: Sequence[int], f: float) -> float:
        return float(np.sum(self.latency_model.predict(np.asarray(lengths), f)))

    def t_ref_total(self, lengths: Sequence[int]) -> float:
        return float(np.sum(self.latency_model.t_ref(np.asarray(lengths))))

    def energy_total(self, T_ref: float, D: float, f) -> np.ndarray:
        f = np.asarray(f, np.float64)
        busy = T_ref * (self.latency_model.f_ref / f)
        active = self.power_model.predict(f) * busy
        idle = self.p_idle * np.maximum(D - busy, 0.0)
        return active + idle

    def choose_frequency(self, lengths: Sequence[int], D: float,
                         ladder: Optional[np.ndarray] = None
                         ) -> Tuple[float, dict]:
        """Solve Eq. 14 over the discrete ladder.

        The info dict always carries a stable ``reason`` code —
        ``empty_queue`` (idle floor), ``infeasible_fmax`` (no ladder point
        meets D; protect the SLO at f_max), or ``optimal`` (Eq. 14 argmin)
        — plus the queue state, so every prefill clock choice is auditable
        in the DVFS decision log."""
        ladder = self.hw.ladder() if ladder is None else np.asarray(ladder)
        if len(lengths) == 0:
            return float(ladder[0]), {"feasible": True, "busy": 0.0,
                                      "energy": self.p_idle * D,
                                      "reason": "empty_queue",
                                      "n_jobs": 0, "D": float(D)}
        T_ref = self.t_ref_total(lengths)
        busy = T_ref * (self.latency_model.f_ref / ladder)
        feasible = busy <= D
        if not feasible.any():
            f = float(ladder[-1])
            return f, {"feasible": False, "busy": float(busy[-1]),
                       "energy": float(self.energy_total(T_ref, D, f)),
                       "reason": "infeasible_fmax",
                       "n_jobs": len(lengths), "D": float(D)}
        E = self.energy_total(T_ref, D, ladder)
        E = np.where(feasible, E, np.inf)
        i = int(np.argmin(E))
        return float(ladder[i]), {"feasible": True, "busy": float(busy[i]),
                                  "energy": float(E[i]),
                                  "reason": "optimal",
                                  "n_jobs": len(lengths), "D": float(D)}


def deadline_from_queue(queue_lengths: Sequence[int], slo_ttft: float,
                        oldest_wait: float) -> float:
    """SLO interval D: time remaining until the oldest queued request would
    violate its TTFT target (the queueing signal of Fig. 6)."""
    return max(slo_ttft - oldest_wait, 1e-3)
