"""Declarative SLO alerting over the metrics timeline.

Three rule kinds, evaluated at block cadence against the ``MetricsRegistry``
snapshot timeline (never against live device state — an alert decision is a
pure function of the recorded timeline, which is what makes every firing
*auditable*: replaying the rule over the same snapshots must reproduce it):

* ``threshold`` — a gauge/counter series (or a histogram quantile via the
  shared ``quantile_from_buckets`` helper) compared against a bound.
* ``burn_rate`` — the SRE error-budget burn multiple over a trailing
  window: ``(Δbad / Δ(bad+good)) / (1 - slo_target)`` computed from the
  timeline deltas between ``query(now - window)`` and ``query(now)``;
  fires when the multiple exceeds ``threshold`` (1.0 = burning budget
  exactly as fast as the SLO allows).
* ``baseline_delta`` — relative deviation of a series from a fixed
  expected baseline (e.g. energy-per-token drifting from a calibrated
  value).

Firings are edge-triggered (a rule increments ``greenllm_alerts_total
{rule,severity}`` when it *transitions* into the firing state, and the
engine keeps a resolved/firing state machine), logged as typed ``Alert``
records, and mirrored as tracer instant events when a tracer is attached.
``audit()`` re-evaluates every logged firing from the timeline and raises
if any is not reproducible.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Tuple

from .metrics import MetricsRegistry, quantile_from_buckets

__all__ = ["AlertRule", "Alert", "AlertEngine"]


@dataclasses.dataclass(frozen=True)
class AlertRule:
    """One declarative rule.  Use the classmethod constructors — the flat
    field set is the union over the three kinds."""
    name: str
    kind: str                           # threshold | burn_rate | baseline_delta
    metric: str = ""                    # family name (threshold/baseline)
    labels: Mapping[str, str] = dataclasses.field(default_factory=dict)
    op: str = ">"                       # threshold comparison: > or <
    bound: float = 0.0                  # threshold bound / baseline value
    quantile: Optional[float] = None    # threshold over histogram quantile
    window_s: float = 1.0               # burn_rate trailing window
    slo_target: float = 0.95            # burn_rate availability target
    burn_threshold: float = 1.0         # burn multiple that fires
    min_events: int = 1                 # burn_rate min Δtotal (debounce)
    bad_labels: Mapping[str, str] = dataclasses.field(default_factory=dict)
    good_labels: Mapping[str, str] = dataclasses.field(default_factory=dict)
    rel_delta: float = 0.1              # baseline_delta relative deviation
    severity: str = "warning"

    @classmethod
    def threshold(cls, name: str, metric: str, op: str, bound: float, *,
                  labels: Optional[Mapping[str, str]] = None,
                  quantile: Optional[float] = None,
                  severity: str = "warning") -> "AlertRule":
        if op not in (">", "<"):
            raise ValueError(f"threshold op must be '>' or '<', got {op!r}")
        return cls(name=name, kind="threshold", metric=metric, op=op,
                   bound=bound, labels=dict(labels or {}), quantile=quantile,
                   severity=severity)

    @classmethod
    def burn_rate(cls, name: str, metric: str, *,
                  bad_labels: Mapping[str, str],
                  good_labels: Mapping[str, str],
                  window_s: float, slo_target: float,
                  burn_threshold: float = 1.0, min_events: int = 1,
                  severity: str = "page") -> "AlertRule":
        if not 0.0 <= slo_target < 1.0:
            raise ValueError(
                f"slo_target must be in [0, 1) — a target of 1.0 has no "
                f"error budget to burn (got {slo_target})")
        return cls(name=name, kind="burn_rate", metric=metric,
                   bad_labels=dict(bad_labels), good_labels=dict(good_labels),
                   window_s=window_s, slo_target=slo_target,
                   burn_threshold=burn_threshold, min_events=min_events,
                   severity=severity)

    @classmethod
    def baseline_delta(cls, name: str, metric: str, baseline: float,
                       rel_delta: float, *,
                       labels: Optional[Mapping[str, str]] = None,
                       severity: str = "warning") -> "AlertRule":
        if baseline == 0.0:
            raise ValueError("baseline_delta needs a nonzero baseline")
        return cls(name=name, kind="baseline_delta", metric=metric,
                   bound=baseline, rel_delta=rel_delta,
                   labels=dict(labels or {}), severity=severity)


@dataclasses.dataclass(frozen=True)
class Alert:
    """One edge-triggered firing (or resolution) of a rule."""
    t: float
    rule: str
    severity: str
    value: float                        # the quantity the rule compared
    fired: bool                         # False = resolved transition
    message: str = ""


def _select(snap: Mapping[str, float], metric: str,
            labels: Mapping[str, str]) -> List[Tuple[str, float]]:
    """All series of family ``metric`` whose label set includes ``labels``
    (matched on the flat-key text; label values here are trusted metric
    constants, not hostile strings)."""
    out = []
    want = [f'{k}="{v}"' for k, v in labels.items()]
    for key, val in snap.items():
        if not key.startswith(metric):
            continue
        rest = key[len(metric):]
        if rest and not rest.startswith("{"):
            continue                     # longer family name sharing a prefix
        if all(w in rest for w in want):
            out.append((key, val))
    return out


class AlertEngine:
    """Evaluate rules against a registry's timeline at block cadence."""

    def __init__(self, registry: MetricsRegistry, rules, tracer=None):
        self.registry = registry
        self.rules: List[AlertRule] = list(rules)
        names = [r.name for r in self.rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate rule names: {names}")
        self.tracer = tracer
        self._counter = registry.counter(
            "greenllm_alerts_total", "alert rule firings (edge-triggered)",
            ("rule", "severity"))
        # pre-bind children so alert series exist at 0 before any firing
        self._children = {r.name: self._counter.labels(rule=r.name,
                                                       severity=r.severity)
                          for r in self.rules}
        self._firing: Dict[str, bool] = {r.name: False for r in self.rules}
        self.log: List[Alert] = []

    # -- rule evaluation (pure functions of the timeline) --------------------
    def _eval(self, rule: AlertRule, now: float) -> Tuple[float, bool]:
        """(value, firing) for ``rule`` at ``now``, reading only timeline
        snapshots — so ``audit()`` can reproduce every decision."""
        snap = self.registry.query(now)
        if snap is None:
            return math.nan, False
        if rule.kind == "threshold":
            if rule.quantile is not None:
                pairs = []
                for key, val in _select(snap, rule.metric + "_bucket",
                                        rule.labels):
                    le = key.rsplit('le="', 1)[1].split('"', 1)[0]
                    pairs.append((float(le), val))
                value = quantile_from_buckets(pairs, rule.quantile) \
                    if pairs else math.nan
            else:
                series = _select(snap, rule.metric, rule.labels)
                value = max((v for _, v in series), default=math.nan)
            if value != value:
                return value, False
            return value, (value > rule.bound if rule.op == ">"
                           else value < rule.bound)
        if rule.kind == "burn_rate":
            past = self.registry.query(now - rule.window_s) or {}

            def delta(labels):
                cur = sum(v for _, v in
                          _select(snap, rule.metric, labels))
                old = sum(v for _, v in
                          _select(past, rule.metric, labels))
                return max(cur - old, 0.0)

            bad = delta(rule.bad_labels)
            total = bad + delta(rule.good_labels)
            if total < rule.min_events:
                return 0.0, False
            burn = (bad / total) / (1.0 - rule.slo_target)
            return burn, burn >= rule.burn_threshold
        if rule.kind == "baseline_delta":
            series = _select(snap, rule.metric, rule.labels)
            value = max((v for _, v in series), default=math.nan)
            if value != value:
                return value, False
            dev = abs(value - rule.bound) / abs(rule.bound)
            return dev, dev > rule.rel_delta
        raise ValueError(f"unknown rule kind {rule.kind!r}")

    def evaluate(self, now: float) -> List[Alert]:
        """One evaluation round; returns the transitions it produced."""
        fired: List[Alert] = []
        for rule in self.rules:
            value, firing = self._eval(rule, now)
            was = self._firing[rule.name]
            if firing == was:
                continue
            self._firing[rule.name] = firing
            a = Alert(t=now, rule=rule.name, severity=rule.severity,
                      value=value, fired=firing,
                      message=f"{rule.kind} {'fired' if firing else 'resolved'}"
                              f" at {value:.4g}")
            self.log.append(a)
            fired.append(a)
            if firing:
                self._children[rule.name].inc()
            if self.tracer is not None:
                self.tracer.instant(
                    "alert" if firing else "alert_resolved", -1, now,
                    "alerts", rule=rule.name, severity=rule.severity,
                    value=float(value))
        return fired

    def firing(self) -> List[str]:
        """Names of the rules currently in the firing state."""
        return [n for n, f in self._firing.items() if f]

    def audit(self) -> int:
        """Re-derive every logged firing from the timeline: each ``fired``
        record's rule must evaluate to firing at the recorded instant with
        the recorded value.  Returns the number of firings audited; raises
        AssertionError on any non-reproducible alert."""
        by_name = {r.name: r for r in self.rules}
        audited = 0
        for a in self.log:
            if not a.fired:
                continue
            value, firing = self._eval(by_name[a.rule], a.t)
            assert firing, (
                f"alert {a.rule!r} @ t={a.t:.4f} does not reproduce from "
                f"the timeline (re-evaluated value {value:.4g})")
            assert value == a.value or (value != value and a.value != a.value), (
                f"alert {a.rule!r} @ t={a.t:.4f}: logged value {a.value!r} "
                f"!= timeline value {value!r}")
            audited += 1
        return audited
