"""Dual-loop decode DVFS controller (paper §3.3, Figure 9).

Coarse loop (every 200 ms): sliding-window TPS -> offline TPS->frequency
lookup -> frequency *band* (optimal clock + two neighbours), applied only
after the TPS bucket is stable for 3 consecutive intervals (hysteresis).

Fine loop (every 20 ms): P95 TBT margin vs the 100 ms SLO:
    margin > 1.0   -> +15 MHz (up to band upper bound)
    margin < 0.65  -> -15 MHz (down to band lower bound)
    else           -> hold
Each adjustment is rate-limited to one f_step per tick.

Band adaptation (every 6 s): if >80 % of fine adjustments saturated a band
bound, shift the lookup entry one step in that direction (§3.3.3).

All decisions happen outside the GPU/TPU execution path.

Decision logging: installing ``on_decision`` (a ``core.tracing.Tracer.bind``
callback, signature ``cb(t, phase, freq_mhz, reason, **inputs)``) makes
every tick that moves — or deliberately holds — the clock auditable: the
coarse loop logs band shifts and occupancy boosts, the fine loop logs every
tick with its p95-TBT margin, band adaptation logs table shifts.  Reason
codes are stable strings (see README "Observability").  ``on_decision is
None`` (the default) skips every site — zero overhead when untraced.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .hardware import HardwareProfile
from .models import TPSFreqTable
from .telemetry import TPSMeter, TBTMeter, OccupancyMeter


@dataclasses.dataclass
class DecodeControllerConfig:
    tbt_slo: float = 0.100          # s, P95 target
    fine_period: float = 0.020      # s
    coarse_period: float = 0.200    # s
    adapt_period: float = 6.0       # s
    up_margin: float = 1.0
    down_margin: float = 0.65
    hysteresis: int = 3             # consecutive coarse intervals
    adapt_bias: float = 0.80        # fraction of saturated adjustments
    tbt_window: float = 1.0         # s of TBT samples for the P95
    # memory pressure (paged serving): sustained KV-pool occupancy above
    # occ_high raises the coarse band by one f_step per pressured coarse
    # tick (draining streams before the pool forces preemption — recompute
    # costs more energy than the extra clock); the boost decays one step per
    # un-pressured tick, so the band returns to the profiled table value
    # once the episode ends instead of ratcheting permanently
    occ_high: float = 0.85
    occ_window: float = 1.0         # s of occupancy samples for the mean


class DualLoopController:
    def __init__(self, hw: HardwareProfile, table: TPSFreqTable,
                 cfg: DecodeControllerConfig = DecodeControllerConfig()):
        self.hw = hw
        self.table = table
        self.cfg = cfg
        self.freq = hw.f_max
        self.band = (hw.f_max - hw.f_step, hw.f_max, hw.f_max)
        self.tps_meter = TPSMeter(cfg.coarse_period)
        self.tbt_meter = TBTMeter(cfg.tbt_window)
        self.occ_meter = OccupancyMeter(cfg.occ_window)
        self._occ_boost = 0     # band overlay steps under memory pressure
        self._bucket: Optional[int] = None
        self._pending_bucket: Optional[int] = None
        self._pending_count = 0
        self._next_fine = 0.0
        self._next_coarse = 0.0
        self._next_adapt = cfg.adapt_period
        self._adjust_events: List[int] = []   # +1 hit band top, -1 hit bottom, 0 inside
        self.history: List[Tuple[float, float, float]] = []  # (t, freq, tps)
        # DVFS decision log sink: cb(t, phase, freq_mhz, reason, **inputs)
        self.on_decision = None

    # -- telemetry ingestion ----------------------------------------------------
    def record_tokens(self, t: float, n: int, tbt: float) -> None:
        self.tps_meter.record_tokens(t, n)
        if n > 0 and tbt > 0:
            self.tbt_meter.record_tbt(t, tbt)

    def record_occupancy(self, t: float, occupancy: float) -> None:
        """KV page-pool occupancy in [0, 1] (paged serving engine)."""
        self.occ_meter.record(t, occupancy)

    # -- control ticks -----------------------------------------------------------
    def maybe_tick(self, now: float) -> float:
        """Advance all loops up to ``now``; returns the current frequency."""
        while self._next_fine <= now:
            if self._next_coarse <= self._next_fine:
                self._coarse_tick(self._next_coarse)
                self._next_coarse += self.cfg.coarse_period
            if self._next_adapt <= self._next_fine:
                self._adapt_tick(self._next_adapt)
                self._next_adapt += self.cfg.adapt_period
            self._fine_tick(self._next_fine)
            self._next_fine += self.cfg.fine_period
        return self.freq

    def _coarse_tick(self, t: float) -> None:
        tps = self.tps_meter.tps(t)
        bucket = self.table.bucket(tps)
        prev_band, prev_freq = self.band, self.freq
        adopted = None        # reason if the TPS bucket moved the band
        boosted = None        # reason if memory pressure moved the band
        if bucket == self._bucket:
            self._pending_bucket = None
            self._pending_count = 0
        elif bucket == self._pending_bucket:
            self._pending_count += 1
            if self._pending_count >= self.cfg.hysteresis:
                self._bucket = bucket
                self.band = self.table.band(bucket, self.hw.f_min, self.hw.f_max)
                self._pending_bucket = None
                self._pending_count = 0
                adopted = "tps_band_shift"
        else:
            self._pending_bucket = bucket
            self._pending_count = 1
        if self._bucket is None:  # first observation: adopt immediately
            self._bucket = bucket
            self.band = self.table.band(bucket, self.hw.f_min, self.hw.f_max)
            adopted = "tps_band_init"
        # memory pressure: the band is the table's entry for the current
        # bucket plus a decaying boost — one f_step up per pressured coarse
        # tick, one down per calm tick — so decode drains streams before the
        # pool preempts, and the band returns to the profiled value once the
        # episode ends (no permanent ratchet, no table corruption).  The
        # fine loop still rules within the (possibly raised) band.
        occ = float("nan")
        if len(self.occ_meter):
            occ = self.occ_meter.mean(t)  # nan if the window just drained
            if occ > self.cfg.occ_high:
                self._occ_boost += 1
                boosted = "occ_pressure"
            elif self._occ_boost:
                self._occ_boost -= 1
                boosted = "occ_decay"
            if self._bucket is not None:
                s, fm = self.hw.f_step, self.hw.f_max
                lo, mid, hi = self.table.band(self._bucket, self.hw.f_min, fm)
                # saturate at the step count that pins lo to f_max: further
                # growth changes nothing but would stretch the decay tail
                self._occ_boost = min(self._occ_boost,
                                      int(np.ceil((fm - lo) / s)))
                b = self._occ_boost * s
                self.band = (min(lo + b, fm), min(mid + b, fm),
                             min(hi + b, fm))
                self.freq = float(np.clip(self.freq, self.band[0],
                                          self.band[2]))
        self.history.append((t, self.freq, tps))
        if self.on_decision is not None and (
                adopted or boosted or self.band != prev_band
                or self.freq != prev_freq):
            self.on_decision(
                t, "decode", self.freq,
                adopted or boosted or "band_reclip",
                tps=tps, bucket=self._bucket, occ=occ,
                occ_boost=self._occ_boost,
                band_lo=self.band[0], band_hi=self.band[2])

    def _fine_tick(self, t: float) -> None:
        p95 = self.tbt_meter.p95(t)
        # nan-safe: an empty window is "no data", not "fast" — hold the
        # clock rather than reading nan as a zero-latency green light
        if not p95 > 0.0:
            return
        margin = p95 / self.cfg.tbt_slo
        lo, mid, hi = self.band
        step = self.hw.f_step
        if margin > self.cfg.up_margin:
            new = min(self.freq + step, hi)
            self._adjust_events.append(+1 if new == hi else 0)
            reason = "tbt_pressure_sat" if new == hi else "tbt_pressure"
        elif margin < self.cfg.down_margin:
            new = max(self.freq - step, lo)
            self._adjust_events.append(-1 if new == lo else 0)
            reason = "tbt_slack_sat" if new == lo else "tbt_slack"
        else:
            new = self.freq
            reason = "tbt_hold"
        # keep the set point inside the (possibly re-centred) band
        self.freq = float(np.clip(new, lo, hi))
        if self.on_decision is not None:
            self.on_decision(t, "decode", self.freq, reason,
                             p95_tbt=p95, margin=margin,
                             band_lo=lo, band_hi=hi)

    def _adapt_tick(self, t: float) -> None:
        ev = self._adjust_events
        self._adjust_events = []
        if not ev or self._bucket is None:
            return
        n = len(ev)
        up = sum(1 for e in ev if e > 0)
        down = sum(1 for e in ev if e < 0)
        if up / n > self.cfg.adapt_bias:
            self.table.shift(self._bucket, +1, self.hw.f_min, self.hw.f_max)
            reason = "band_adapt_up"
        elif down / n > self.cfg.adapt_bias:
            self.table.shift(self._bucket, -1, self.hw.f_min, self.hw.f_max)
            reason = "band_adapt_down"
        else:
            return
        self.band = self.table.band(self._bucket, self.hw.f_min, self.hw.f_max)
        if self.on_decision is not None:
            self.on_decision(t, "decode", self.freq, reason,
                             saturated_up=up, saturated_down=down, ticks=n,
                             band_lo=self.band[0], band_hi=self.band[2])


class MaxFreqController:
    """DefaultNV baseline: performance governor pinned near f_max (Fig. 1a)."""

    def __init__(self, hw: HardwareProfile):
        self.hw = hw
        self.freq = hw.f_max
        self.history: List[Tuple[float, float, float]] = []
        self.on_decision = None   # never fires: the clock never moves

    def record_tokens(self, t, n, tbt):
        pass

    def record_occupancy(self, t, occupancy):
        pass

    def maybe_tick(self, now: float) -> float:
        return self.freq


class FixedFreqController:
    """Fixed-clock baseline (used for the Fig. 3c total-energy sweep)."""

    def __init__(self, hw: HardwareProfile, freq: float):
        self.hw = hw
        self.freq = float(freq)
        self.on_decision = None   # never fires: the clock never moves

    def record_tokens(self, t, n, tbt):
        pass

    def record_occupancy(self, t, occupancy):
        pass

    def maybe_tick(self, now: float) -> float:
        return self.freq
