"""Decode-time state: full and ring-buffer KV caches, SSM and RG-LRU states.

Caches are plain pytrees so they flow through jit / scan / shard_map.  All
buffers have static shapes; the current stream position is passed separately
as a traced scalar.  Ring buffers store entries at ``slot = position % W`` and
reconstruct absolute positions arithmetically for masking + RoPE.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig, FULL_ATTN, LOCAL_ATTN, SSM, RGLRU


def attn_buffer_len(cfg: ModelConfig, kind: str, max_len: int, long_context: bool) -> int:
    if kind == LOCAL_ATTN and cfg.window:
        return min(cfg.window, max_len)
    if long_context and kind == FULL_ATTN and not cfg.is_subquadratic:
        # beyond-paper: windowed long-context decode for full-attention archs
        return min(cfg.long_context_window, max_len)
    return max_len


def init_attn_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                    long_context: bool = False, dtype=jnp.bfloat16) -> Dict:
    S = attn_buffer_len(cfg, kind, max_len, long_context)
    shape = (batch, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.kv_quant:
        sshape = (batch, S, cfg.num_kv_heads, 1)
        return {"k": jnp.zeros(shape, jnp.int8), "v": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(sshape, jnp.float32),
                "v_s": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    nh, hd, st = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    conv_ch = cfg.ssm_inner + 2 * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, nh, hd, st), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     long_context: bool = False, dtype=jnp.bfloat16) -> Dict:
    if kind in (FULL_ATTN, LOCAL_ATTN):
        return init_attn_cache(cfg, kind, batch, max_len, long_context, dtype)
    if kind == SSM:
        return init_ssm_cache(cfg, batch, dtype)
    if kind == RGLRU:
        return init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


def ring_slot_positions(buf_len: int, pos):
    """Absolute position stored in each slot of a ring buffer of length
    ``buf_len`` when the *next* token to be written has position ``pos``
    (i.e. entries written so far are positions 0..pos-1, the last ``buf_len``
    of them resident).  Unfilled slots get negative values (masked).

    ``pos`` may be a scalar (one shared stream position, returns (buf_len,))
    or a (B,) vector of per-slot stream positions (returns (B, buf_len)).
    """
    j = jnp.arange(buf_len, dtype=jnp.int32)
    last = jnp.asarray(pos, jnp.int32)[..., None] - 1   # (..., 1)
    p = last - ((last - j) % buf_len)
    p = jnp.where(p < 0, -1, p).astype(jnp.int32)
    return p if p.ndim > 1 else p.reshape(buf_len)


def quantize_kv(x):
    """(..., hd) -> int8 values + f32 scale on the trailing dim."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def cache_write_decode(cache: Dict, k_new, v_new, pos):
    """Write one token (B,1,KH,hd) at position ``pos``.

    ``pos`` is either a traced scalar (all rows share one stream position —
    the lockstep path) or a (B,) int32 vector of per-slot positions (the
    slot-native serving path: each row writes at its own ring slot).
    """
    buf_len = cache["k"].shape[1]
    pos = jnp.asarray(pos)
    if "k_s" in cache:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        qcache = {"k": cache["k"], "v": cache["v"]}
        scache = {"k": cache["k_s"], "v": cache["v_s"]}
        out = cache_write_decode(qcache, kq, vq, pos)
        sc = cache_write_decode(scache, ks, vs, pos)
        return {"k": out["k"], "v": out["v"], "k_s": sc["k"], "v_s": sc["v"]}
    if pos.ndim == 1:
        B = k_new.shape[0]
        slots = jnp.mod(pos, buf_len)
        k = cache["k"].at[jnp.arange(B), slots].set(
            k_new[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[jnp.arange(B), slots].set(
            v_new[:, 0].astype(cache["v"].dtype))
        return {"k": k, "v": v}
    slot = jnp.mod(pos, buf_len)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    return {"k": k, "v": v}


def cache_kv_arrays(cache: Dict, dtype=jnp.bfloat16):
    """Return dequantized (k, v) ready for attention."""
    if "k_s" in cache:
        return (dequantize_kv(cache["k"], cache["k_s"], dtype),
                dequantize_kv(cache["v"], cache["v_s"], dtype))
    return cache["k"].astype(dtype), cache["v"].astype(dtype)


def cache_write_prefill(cache: Dict, k_seq, v_seq):
    """Write a prefill sequence (B,S,KH,hd) into a fresh buffer.

    If S > buf_len (windowed cache shorter than the prompt), only the last
    buf_len entries are retained, placed at their ring slots.
    """
    if "k_s" in cache:
        kq, ks = quantize_kv(k_seq)
        vq, vs = quantize_kv(v_seq)
        out = cache_write_prefill({"k": cache["k"], "v": cache["v"]}, kq, vq)
        scales = cache_write_prefill({"k": cache["k_s"], "v": cache["v_s"]}, ks, vs)
        return {"k": out["k"], "v": out["v"],
                "k_s": scales["k"], "v_s": scales["v"]}
    B, S = k_seq.shape[:2]
    buf_len = cache["k"].shape[1]
    if S <= buf_len:
        k = jax.lax.dynamic_update_slice(cache["k"], k_seq.astype(cache["k"].dtype),
                                         (0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_seq.astype(cache["v"].dtype),
                                         (0, 0, 0, 0))
        return {"k": k, "v": v}
    tail_pos = jnp.arange(S - buf_len, S)
    slots = jnp.mod(tail_pos, buf_len)
    k = cache["k"].at[:, slots].set(k_seq[:, S - buf_len:].astype(cache["k"].dtype))
    v = cache["v"].at[:, slots].set(v_seq[:, S - buf_len:].astype(cache["v"].dtype))
    return {"k": k, "v": v}


def cache_write_prefill_slot(cache: Dict, k_seq, v_seq, slot):
    """Write a (bucket-padded) prefill sequence into ONE row of a batch cache.

    ``cache`` leaves are batch-shaped (B, buf_len, KH, hd); ``k_seq``/``v_seq``
    are (1, S_pad, KH, hd); ``slot`` is a traced row index.  Requires
    S_pad <= buf_len (the serving engine guards buckets against the smallest
    attention buffer and falls back to the reference path otherwise).  Pad
    positions >= the true prompt length hold garbage K/V: they are masked by
    the ring-position arithmetic until the decode loop overwrites each one at
    exactly its position, so they are never read.
    """
    if "k_s" in cache:
        kq, ks = quantize_kv(k_seq)
        vq, vs = quantize_kv(v_seq)
        out = cache_write_prefill_slot({"k": cache["k"], "v": cache["v"]},
                                       kq, vq, slot)
        sc = cache_write_prefill_slot({"k": cache["k_s"], "v": cache["v_s"]},
                                      ks, vs, slot)
        return {"k": out["k"], "v": out["v"], "k_s": sc["k"], "v_s": sc["v"]}
    S = k_seq.shape[1]
    buf_len = cache["k"].shape[1]
    assert S <= buf_len, (
        f"slot prefill bucket {S} exceeds cache buffer {buf_len}")
    k = jax.lax.dynamic_update_slice(cache["k"], k_seq.astype(cache["k"].dtype),
                                     (slot, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_seq.astype(cache["v"].dtype),
                                     (slot, 0, 0, 0))
    return {"k": k, "v": v}


def cache_write_chunk_slot(cache: Dict, k_seq, v_seq, slot, start, length):
    """Write one prompt *chunk* (1, S_pad, KH, hd) into row ``slot`` of a
    batch cache at ring slots for absolute positions ``start..start+length-1``.

    Unlike ``cache_write_prefill_slot`` (which always writes from slot 0 and
    relies on position masking to hide pad garbage), chunk writes land at ring
    slots that may wrap onto *valid earlier context*, so pad positions
    ``>= length`` must not be written at all: their scatter indices are pushed
    out of range and dropped (``mode="drop"``).
    """
    if "k_s" in cache:
        kq, ks = quantize_kv(k_seq)
        vq, vs = quantize_kv(v_seq)
        out = cache_write_chunk_slot({"k": cache["k"], "v": cache["v"]},
                                     kq, vq, slot, start, length)
        sc = cache_write_chunk_slot({"k": cache["k_s"], "v": cache["v_s"]},
                                    ks, vs, slot, start, length)
        return {"k": out["k"], "v": out["v"], "k_s": sc["k"], "v_s": sc["v"]}
    S = k_seq.shape[1]
    buf_len = cache["k"].shape[1]
    i = jnp.arange(S, dtype=jnp.int32)
    slots = jnp.mod(jnp.asarray(start, jnp.int32) + i, buf_len)
    slots = jnp.where(i < jnp.asarray(length, jnp.int32), slots, buf_len)
    k = cache["k"].at[slot, slots].set(k_seq[0].astype(cache["k"].dtype),
                                       mode="drop")
    v = cache["v"].at[slot, slots].set(v_seq[0].astype(cache["v"].dtype),
                                       mode="drop")
    return {"k": k, "v": v}


def cache_row_kv_arrays(cache: Dict, slot, dtype=jnp.bfloat16):
    """Dequantized (k, v) of ONE batch row ``slot`` (traced), shape
    (1, buf_len, KH, hd) — the past-context read of the chunked prefill."""
    def row(x):
        return jax.lax.dynamic_slice_in_dim(x, jnp.asarray(slot, jnp.int32),
                                            1, axis=0)
    sub = {kk: row(vv) for kk, vv in cache.items()}
    return cache_kv_arrays(sub, dtype)


# -- paged pool layout (serving engine, EngineConfig.paged=True) ---------------
#
# Full-length attention buffers are replaced by a pool of fixed-size pages
# shared by every stream: leaves are (num_pages, page_size, KH, hd) with NO
# batch dimension (keys "kp"/"vp" so tree ops and the engine's dense-cache
# ctx slicing never confuse the two layouts).  Streams address the pool
# through an int32 page table (B, n_pages) maintained by
# ``serving.pager.PageAllocator``; logical position p of a stream lives at
# pool[page_table[b, p // ps], p % ps].  Pages are linear (no ring wrap):
# chains grow with the context, so absolute position == logical index.


# Capacity axis of a *stacked* cache leaf ((n_rep,) + leaf shape, see
# transformer.init_cache): batch rows for dense/ring/recurrent leaves, the
# page axis for paged pool leaves.  The serving mesh shards exactly this
# axis along 'data' (launch.shardings.serving_cache_specs) — both are
# capacity, neither participates in a cross-row reduction, so sharding it
# is placement only and the bits cannot move.
STACKED_CAPACITY_AXIS = 1


def is_paged(cache: Dict) -> bool:
    return "kp" in cache


def init_paged_attn_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                          dtype=jnp.bfloat16) -> Dict:
    shape = (num_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
    if cfg.kv_quant:
        sshape = (num_pages, page_size, cfg.num_kv_heads, 1)
        return {"kp": jnp.zeros(shape, jnp.int8),
                "vp": jnp.zeros(shape, jnp.int8),
                "kp_s": jnp.zeros(sshape, jnp.float32),
                "vp_s": jnp.zeros(sshape, jnp.float32)}
    return {"kp": jnp.zeros(shape, dtype), "vp": jnp.zeros(shape, dtype)}


def paged_key_positions(n_tokens: int, next_pos):
    """Positions (B, n_tokens) of a paged context when the *next* token to be
    written has position ``next_pos`` ((B,) vector or scalar).  Pages are
    linear, so slot j holds position j when j < next_pos and is invalid (-1,
    masked) otherwise — unallocated table entries point at the scratch page
    and are masked here by position alone."""
    j = jnp.arange(n_tokens, dtype=jnp.int32)
    p = jnp.asarray(next_pos, jnp.int32)
    valid = j[None, :] < jnp.atleast_1d(p)[:, None]
    return jnp.where(valid, j[None, :], -1)


def _paged_scatter(pool, values, flat_idx):
    """pool (P, ps, ...) scattered at token-flat indices (N,) with OOB drop."""
    P, ps = pool.shape[:2]
    flat = pool.reshape((P * ps,) + pool.shape[2:])
    flat = flat.at[flat_idx].set(values.astype(pool.dtype), mode="drop")
    return flat.reshape(pool.shape)


def paged_cache_write_decode(cache: Dict, k_new, v_new, pos, page_table):
    """Write one token (B,1,KH,hd) per stream at position ``pos`` (B,) via the
    page table (B, n_pages).  Rows whose table points at the scratch page
    (freed slots held in the batch) scribble harmlessly on scratch."""
    if "kp_s" in cache:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        out = paged_cache_write_decode({"kp": cache["kp"], "vp": cache["vp"]},
                                       kq, vq, pos, page_table)
        sc = paged_cache_write_decode({"kp": cache["kp_s"], "vp": cache["vp_s"]},
                                      ks, vs, pos, page_table)
        return {"kp": out["kp"], "vp": out["vp"],
                "kp_s": sc["kp"], "vp_s": sc["vp"]}
    ps = cache["kp"].shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    phys = jnp.take_along_axis(page_table, (pos // ps)[:, None], axis=1)[:, 0]
    flat_idx = phys * ps + pos % ps
    return {"kp": _paged_scatter(cache["kp"], k_new[:, 0], flat_idx),
            "vp": _paged_scatter(cache["vp"], v_new[:, 0], flat_idx)}


def paged_cache_write_chunk(cache: Dict, k_seq, v_seq, page_table_row, start,
                            length):
    """Write one prompt chunk (1, S_pad, KH, hd) at positions
    ``start..start+length-1`` of the stream whose table row (n_pages,) is
    given; pads >= length are dropped (same contract as
    ``cache_write_chunk_slot``)."""
    if "kp_s" in cache:
        kq, ks = quantize_kv(k_seq)
        vq, vs = quantize_kv(v_seq)
        out = paged_cache_write_chunk({"kp": cache["kp"], "vp": cache["vp"]},
                                      kq, vq, page_table_row, start, length)
        sc = paged_cache_write_chunk(
            {"kp": cache["kp_s"], "vp": cache["vp_s"]},
            ks, vs, page_table_row, start, length)
        return {"kp": out["kp"], "vp": out["vp"],
                "kp_s": sc["kp"], "vp_s": sc["vp"]}
    P, ps = cache["kp"].shape[:2]
    S = k_seq.shape[1]
    i = jnp.arange(S, dtype=jnp.int32)
    posi = jnp.asarray(start, jnp.int32) + i
    phys = page_table_row[posi // ps]
    flat_idx = phys * ps + posi % ps
    flat_idx = jnp.where(i < jnp.asarray(length, jnp.int32), flat_idx, P * ps)
    return {"kp": _paged_scatter(cache["kp"], k_seq[0], flat_idx),
            "vp": _paged_scatter(cache["vp"], v_seq[0], flat_idx)}


def paged_cache_kv_arrays(cache: Dict, page_table, dtype=jnp.bfloat16):
    """Gather the pages of ``page_table`` (B, n_pages) into dense, dequantized
    (k, v) of shape (B, n_pages*ps, KH, hd), position == index (linear pages).

    The gather width is set by the *caller-sliced* table (ctx bucketing: the
    engine passes only the pages covering the current context bucket), which
    is what bounds compile count and per-step read volume.
    """
    B, n = page_table.shape
    ps = cache["kp"].shape[1]

    def gather(pool):
        g = pool[page_table]                       # (B, n, ps, KH, hd)
        return g.reshape(B, n * ps, *pool.shape[2:])

    if "kp_s" in cache:
        return (dequantize_kv(gather(cache["kp"]), gather(cache["kp_s"]), dtype),
                dequantize_kv(gather(cache["vp"]), gather(cache["vp_s"]), dtype))
    return gather(cache["kp"]).astype(dtype), gather(cache["vp"]).astype(dtype)


def paged_chain_extract(cache: Dict, chain):
    """Gather one stream's page chain out of stacked paged pools.

    ``cache`` leaves are (n_rep, num_pages, page_size, ...); ``chain`` is the
    stream's physical page ids (host list / array).  Returns a parallel dict
    of (n_rep, len(chain), page_size, ...) arrays — the stream's live K/V and
    nothing else, which is what makes replica-to-replica migration cost
    O(context) instead of O(max_len) (no full-length buffer ever moves).
    """
    idx = jnp.asarray(chain, jnp.int32)
    return {k: v[:, idx] for k, v in cache.items()}


def paged_chain_insert(cache: Dict, pages: Dict, chain):
    """Scatter extracted chain pages (``paged_chain_extract`` output) into the
    physical pages ``chain`` of another (or the same) pool.  The destination
    chain must have the same length and page size; dtypes are cast to the
    destination pool's (migration between equal-dtype pools is bit-exact)."""
    idx = jnp.asarray(chain, jnp.int32)
    return {k: cache[k].at[:, idx].set(pages[k].astype(cache[k].dtype))
            for k in cache}


def paged_page_copy(cache: Dict, src, dst):
    """Copy physical page ``src`` onto ``dst`` in every leaf of a stacked
    paged pool dict (leaves (n_rep, num_pages, page_size, ...)).  The
    copy-on-write step of prefix sharing: the allocator swaps a private page
    into a chain, and this moves the shared page's K/V bits onto it so the
    stream's subsequent in-place writes can't perturb other readers."""
    s = jnp.asarray(src, jnp.int32)
    d = jnp.asarray(dst, jnp.int32)
    return {k: v.at[:, d].set(v[:, s]) for k, v in cache.items()}


def cache_row_extract(cache: Dict, slot: int):
    """Copy one batch row out of a stacked dense cache dict (bounded ring
    buffers and recurrent SSM/RG-LRU states): leaves (n_rep, B, ...) ->
    (n_rep, 1, ...).  Ring content is position-aligned (slot = pos % W), so a
    row transplanted into another engine at the same stream position reads
    identically."""
    return {k: v[:, slot:slot + 1] for k, v in cache.items()}


def cache_row_insert(cache: Dict, row: Dict, slot: int):
    """Splice an extracted row (``cache_row_extract`` output) into batch row
    ``slot`` of another stacked dense cache dict."""
    return {k: cache[k].at[:, slot:slot + 1].set(row[k].astype(cache[k].dtype))
            for k in cache}


def state_row_slot(batch_cache, slot):
    """Slice row ``slot`` (traced) out of a batch-shaped recurrent state
    pytree -> leading-dim-1 pytree (chunked prefill resumes from it)."""
    return jax.tree.map(
        lambda x: jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(slot, jnp.int32), 1, axis=0), batch_cache)


def state_write_slot(batch_cache, one_cache, slot):
    """Splice a single-row recurrent state (SSM / RG-LRU pytree, leading dim 1)
    into the batch-shaped state pytree at row ``slot`` (traced)."""
    return jax.tree.map(
        lambda full, one: jax.lax.dynamic_update_slice(
            full, one.astype(full.dtype), (slot,) + (0,) * (one.ndim - 1)),
        batch_cache, one_cache)


def cache_key_positions(cache: Dict, pos, batch: int):
    """Positions (B, buf_len) of cached keys when decoding token ``pos``.

    Handles both the full cache (buf_len >= pos: slot == position) and ring
    buffers uniformly — for a full buffer the ring arithmetic reduces to the
    identity on filled slots.  ``pos`` may be a scalar (shared position) or a
    (B,) vector (slot-native serving: per-row key validity/masking).
    """
    buf_len = cache["k"].shape[1]
    p = ring_slot_positions(buf_len, jnp.asarray(pos) + 1)  # pos already written
    if p.ndim == 2:
        return p
    return jnp.broadcast_to(p[None, :], (batch, buf_len))
