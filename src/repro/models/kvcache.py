"""Decode-time state: full and ring-buffer KV caches, SSM and RG-LRU states.

Caches are plain pytrees so they flow through jit / scan / shard_map.  All
buffers have static shapes; the current stream position is passed separately
as a traced scalar.  Ring buffers store entries at ``slot = position % W`` and
reconstruct absolute positions arithmetically for masking + RoPE.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig, FULL_ATTN, LOCAL_ATTN, SSM, RGLRU


def attn_buffer_len(cfg: ModelConfig, kind: str, max_len: int, long_context: bool) -> int:
    if kind == LOCAL_ATTN and cfg.window:
        return min(cfg.window, max_len)
    if long_context and kind == FULL_ATTN and not cfg.is_subquadratic:
        # beyond-paper: windowed long-context decode for full-attention archs
        return min(cfg.long_context_window, max_len)
    return max_len


def init_attn_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                    long_context: bool = False, dtype=jnp.bfloat16) -> Dict:
    S = attn_buffer_len(cfg, kind, max_len, long_context)
    shape = (batch, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.kv_quant:
        sshape = (batch, S, cfg.num_kv_heads, 1)
        return {"k": jnp.zeros(shape, jnp.int8), "v": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(sshape, jnp.float32),
                "v_s": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    nh, hd, st = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    conv_ch = cfg.ssm_inner + 2 * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, nh, hd, st), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     long_context: bool = False, dtype=jnp.bfloat16) -> Dict:
    if kind in (FULL_ATTN, LOCAL_ATTN):
        return init_attn_cache(cfg, kind, batch, max_len, long_context, dtype)
    if kind == SSM:
        return init_ssm_cache(cfg, batch, dtype)
    if kind == RGLRU:
        return init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


def ring_slot_positions(buf_len: int, pos):
    """Absolute position stored in each slot of a ring buffer of length
    ``buf_len`` when the *next* token to be written has position ``pos``
    (i.e. entries written so far are positions 0..pos-1, the last ``buf_len``
    of them resident).  Unfilled slots get negative values (masked).

    ``pos`` may be a scalar (one shared stream position, returns (buf_len,))
    or a (B,) vector of per-slot stream positions (returns (B, buf_len)).
    """
    j = jnp.arange(buf_len, dtype=jnp.int32)
    last = jnp.asarray(pos, jnp.int32)[..., None] - 1   # (..., 1)
    p = last - ((last - j) % buf_len)
    p = jnp.where(p < 0, -1, p).astype(jnp.int32)
    return p if p.ndim > 1 else p.reshape(buf_len)


def quantize_kv(x):
    """(..., hd) -> int8 values + f32 scale on the trailing dim."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def cache_write_decode(cache: Dict, k_new, v_new, pos):
    """Write one token (B,1,KH,hd) at position ``pos``.

    ``pos`` is either a traced scalar (all rows share one stream position —
    the lockstep path) or a (B,) int32 vector of per-slot positions (the
    slot-native serving path: each row writes at its own ring slot).
    """
    buf_len = cache["k"].shape[1]
    pos = jnp.asarray(pos)
    if "k_s" in cache:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        qcache = {"k": cache["k"], "v": cache["v"]}
        scache = {"k": cache["k_s"], "v": cache["v_s"]}
        out = cache_write_decode(qcache, kq, vq, pos)
        sc = cache_write_decode(scache, ks, vs, pos)
        return {"k": out["k"], "v": out["v"], "k_s": sc["k"], "v_s": sc["v"]}
    if pos.ndim == 1:
        B = k_new.shape[0]
        slots = jnp.mod(pos, buf_len)
        k = cache["k"].at[jnp.arange(B), slots].set(
            k_new[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[jnp.arange(B), slots].set(
            v_new[:, 0].astype(cache["v"].dtype))
        return {"k": k, "v": v}
    slot = jnp.mod(pos, buf_len)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    return {"k": k, "v": v}


def cache_kv_arrays(cache: Dict, dtype=jnp.bfloat16):
    """Return dequantized (k, v) ready for attention."""
    if "k_s" in cache:
        return (dequantize_kv(cache["k"], cache["k_s"], dtype),
                dequantize_kv(cache["v"], cache["v_s"], dtype))
    return cache["k"].astype(dtype), cache["v"].astype(dtype)


def cache_write_prefill(cache: Dict, k_seq, v_seq):
    """Write a prefill sequence (B,S,KH,hd) into a fresh buffer.

    If S > buf_len (windowed cache shorter than the prompt), only the last
    buf_len entries are retained, placed at their ring slots.
    """
    if "k_s" in cache:
        kq, ks = quantize_kv(k_seq)
        vq, vs = quantize_kv(v_seq)
        out = cache_write_prefill({"k": cache["k"], "v": cache["v"]}, kq, vq)
        scales = cache_write_prefill({"k": cache["k_s"], "v": cache["v_s"]}, ks, vs)
        return {"k": out["k"], "v": out["v"],
                "k_s": scales["k"], "v_s": scales["v"]}
    B, S = k_seq.shape[:2]
    buf_len = cache["k"].shape[1]
    if S <= buf_len:
        k = jax.lax.dynamic_update_slice(cache["k"], k_seq.astype(cache["k"].dtype),
                                         (0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_seq.astype(cache["v"].dtype),
                                         (0, 0, 0, 0))
        return {"k": k, "v": v}
    tail_pos = jnp.arange(S - buf_len, S)
    slots = jnp.mod(tail_pos, buf_len)
    k = cache["k"].at[:, slots].set(k_seq[:, S - buf_len:].astype(cache["k"].dtype))
    v = cache["v"].at[:, slots].set(v_seq[:, S - buf_len:].astype(cache["v"].dtype))
    return {"k": k, "v": v}


def cache_write_prefill_slot(cache: Dict, k_seq, v_seq, slot):
    """Write a (bucket-padded) prefill sequence into ONE row of a batch cache.

    ``cache`` leaves are batch-shaped (B, buf_len, KH, hd); ``k_seq``/``v_seq``
    are (1, S_pad, KH, hd); ``slot`` is a traced row index.  Requires
    S_pad <= buf_len (the serving engine guards buckets against the smallest
    attention buffer and falls back to the reference path otherwise).  Pad
    positions >= the true prompt length hold garbage K/V: they are masked by
    the ring-position arithmetic until the decode loop overwrites each one at
    exactly its position, so they are never read.
    """
    if "k_s" in cache:
        kq, ks = quantize_kv(k_seq)
        vq, vs = quantize_kv(v_seq)
        out = cache_write_prefill_slot({"k": cache["k"], "v": cache["v"]},
                                       kq, vq, slot)
        sc = cache_write_prefill_slot({"k": cache["k_s"], "v": cache["v_s"]},
                                      ks, vs, slot)
        return {"k": out["k"], "v": out["v"], "k_s": sc["k"], "v_s": sc["v"]}
    S = k_seq.shape[1]
    buf_len = cache["k"].shape[1]
    assert S <= buf_len, (
        f"slot prefill bucket {S} exceeds cache buffer {buf_len}")
    k = jax.lax.dynamic_update_slice(cache["k"], k_seq.astype(cache["k"].dtype),
                                     (slot, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_seq.astype(cache["v"].dtype),
                                     (slot, 0, 0, 0))
    return {"k": k, "v": v}


def state_write_slot(batch_cache, one_cache, slot):
    """Splice a single-row recurrent state (SSM / RG-LRU pytree, leading dim 1)
    into the batch-shaped state pytree at row ``slot`` (traced)."""
    return jax.tree.map(
        lambda full, one: jax.lax.dynamic_update_slice(
            full, one.astype(full.dtype), (slot,) + (0,) * (one.ndim - 1)),
        batch_cache, one_cache)


def cache_key_positions(cache: Dict, pos, batch: int):
    """Positions (B, buf_len) of cached keys when decoding token ``pos``.

    Handles both the full cache (buf_len >= pos: slot == position) and ring
    buffers uniformly — for a full buffer the ring arithmetic reduces to the
    identity on filled slots.  ``pos`` may be a scalar (shared position) or a
    (B,) vector (slot-native serving: per-row key validity/masking).
    """
    buf_len = cache["k"].shape[1]
    p = ring_slot_positions(buf_len, jnp.asarray(pos) + 1)  # pos already written
    if p.ndim == 2:
        return p
    return jnp.broadcast_to(p[None, :], (batch, buf_len))
