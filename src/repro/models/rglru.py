"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

Block layout follows Griffin's recurrent block: two input branches
(w = lru_width each); branch A goes conv -> RG-LRU, branch B is a GeLU gate;
the product is projected back to d_model.  Gates use per-channel (diagonal)
parameterization (documented simplification of Griffin's block-diagonal
gates — same recurrence, fewer parameters).

Training uses jax.lax.associative_scan over the sequence; decode is a single
recurrent step carrying (h, conv window).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init

_C = 8.0  # Griffin's fixed scaling constant in a_t = exp(-c * softplus(Λ) * r_t)


def init_rglru(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    w = cfg.lru_width or d
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # Λ init so that a^c = exp(-c softplus(Λ)) is in ~[0.9, 0.999]
    lam = jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w))) ) / 1.0
    return {
        "in_x": dense_init(k1, (d, w), dtype=dtype),
        "in_gate": dense_init(k2, (d, w), dtype=dtype),
        "conv_w": (jax.random.normal(k3, (cfg.conv_width, w)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "lam": lam.astype(jnp.float32),
        "rg_w": jnp.zeros((w,), jnp.float32),   # recurrence gate (diagonal)
        "ig_w": jnp.zeros((w,), jnp.float32),   # input gate (diagonal)
        "out": dense_init(k4, (w, d), dtype=dtype),
    }


def _conv(x, wght, b, prefix=None):
    cw = wght.shape[0]
    if prefix is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(cw):
        out = out + xp[:, i: i + x.shape[1]] * wght[i]
    return out + b


def _gates(p, u):
    """u (...,w) f32 -> (a, gated_input) of the RG-LRU recurrence."""
    r = jax.nn.sigmoid(u * p["rg_w"])           # recurrence gate
    i = jax.nn.sigmoid(u * p["ig_w"])           # input gate
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * u)


def rglru_forward(cfg: ModelConfig, p, x, *, return_state: bool = False,
                  cache=None, length=None):
    """x (B,S,d) -> (B,S,d) [, cache].

    ``cache`` ({"h", "conv"}) resumes the recurrence from an earlier segment
    (chunked prefill); ``length`` masks bucket padding — pads get (a=1, b=0),
    an identity step, so ``hh[:, -1]`` is the state at the last valid token
    and the returned conv window ends there too.
    """
    B_, S, _ = x.shape
    u_pre = x @ p["in_x"]                                   # (B,S,w)
    gate = jax.nn.gelu(x @ p["in_gate"], approximate=True)
    prefix = cache["conv"] if cache is not None else None
    u = _conv(u_pre, p["conv_w"], p["conv_b"], prefix).astype(jnp.float32)
    a, b = _gates(p, u)
    if length is not None:
        valid = jnp.arange(S)[None, :, None] < jnp.asarray(length, jnp.int32)
        a = jnp.where(valid, a, 1.0)
        b = jnp.where(valid, b, 0.0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    if cache is not None:
        hh = hh + aa * cache["h"][:, None, :]
    y = (hh.astype(x.dtype) * gate) @ p["out"]
    if not return_state:
        return y
    cw = cfg.conv_width
    if cw > 1:
        lead = prefix.astype(u_pre.dtype) if prefix is not None else \
            jnp.zeros((B_, cw - 1, u_pre.shape[-1]), u_pre.dtype)
        full = jnp.concatenate([lead, u_pre], axis=1)
        end = jnp.asarray(S if length is None else length, jnp.int32)
        conv_state = jax.lax.dynamic_slice_in_dim(full, end, cw - 1, axis=1)
    else:
        conv_state = jnp.zeros((B_, 0, u_pre.shape[-1]), u_pre.dtype)
    return y, {"h": hh[:, -1], "conv": conv_state}


def rglru_decode_step(cfg: ModelConfig, p, x, cache: Dict) -> Tuple[jax.Array, Dict]:
    """x (B,1,d) -> (B,1,d)."""
    u_pre = x[:, 0] @ p["in_x"]                             # (B,w)
    gate = jax.nn.gelu(x[:, 0] @ p["in_gate"], approximate=True)
    window = jnp.concatenate([cache["conv"].astype(u_pre.dtype), u_pre[:, None]], axis=1)
    u = (jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]).astype(jnp.float32)
    a, b = _gates(p, u)
    h = a * cache["h"] + b
    y = ((h.astype(x.dtype) * gate) @ p["out"])[:, None]
    return y, {"h": h, "conv": window[:, 1:].astype(cache["conv"].dtype)}
