"""Composable decoder model covering every assigned architecture family.

Layers are grouped into *stages*: the config's block pattern (e.g. gemma2's
(local, full) or recurrentgemma's (rglru, rglru, local)) is stacked over its
repeat count and executed with ``jax.lax.scan`` — bounded HLO size for the
80-combination multi-pod dry-run — plus an unrolled tail when depth % pattern
!= 0.  Three entry points: ``forward_train`` (full causal sequence),
``prefill`` (sequence -> last logits + caches), ``decode_step`` (one token
against the caches).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .config import ModelConfig, FULL_ATTN, LOCAL_ATTN, SSM, RGLRU
from . import layers as L
from . import kvcache as KV
from .attention import attention
from .moe import init_moe, apply_moe
from .ssm import init_ssm, ssm_forward, ssm_decode_step
from .rglru import init_rglru, rglru_forward, rglru_decode_step


# -- sharding context ------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Activation-sharding helper. ``None`` mesh -> no-op (CPU smoke tests)."""
    mesh: Any = None
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    # decode-time KV cache is sequence-sharded over the model axis (set when
    # kv_heads doesn't divide the model axis): attention then keeps q
    # replicated over heads and lets GSPMD do flash-decode-style partial
    # softmax reductions instead of all-gathering the cache.
    kv_seq_sharded: bool = False

    def spec(self, *dims) -> P:
        ax = []
        for d in dims:
            if d == "b":
                ax.append(self.batch_axes if self.batch_axes else None)
            elif d == "m":
                # model_axis=None => FSDP-style: activations are not
                # tensor-parallel; 'm' constraints dissolve
                ax.append(self.model_axis)
            else:
                ax.append(None)
        return P(*ax)

    def _axis_size(self, entry) -> int:
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def cs(self, x, *dims):
        if self.mesh is None:
            return x
        spec = self.spec(*dims)
        # drop axes that don't divide the corresponding dim (e.g. 12 heads
        # on a 16-way model axis, vocab 50280 on 16 shards)
        entries = []
        for i, e in enumerate(spec):
            if e is None or i >= x.ndim or (
                    x.shape[i] % self._axis_size(e) != 0) or x.shape[i] == 0:
                entries.append(None)
            else:
                entries.append(e)
        if all(e is None for e in entries):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*entries)))


NOSHARD = ShardCtx()


# -- stage decomposition ------------------------------------------------------------

def stages_of(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    out = []
    if cfg.n_pattern_repeats:
        out.append((cfg.block_pattern, cfg.n_pattern_repeats))
    if cfg.tail_kinds:
        out.append((cfg.tail_kinds, 1))
    return out


# -- init ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 6)
    p = {
        "q": L.dense_init(ks[0], (cfg.d_model, cfg.q_dim), dtype=dtype),
        "k": L.dense_init(ks[1], (cfg.d_model, cfg.kv_dim), dtype=dtype),
        "v": L.dense_init(ks[2], (cfg.d_model, cfg.kv_dim), dtype=dtype),
        "o": L.dense_init(ks[3], (cfg.q_dim, cfg.d_model), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["q_b"] = jnp.zeros((cfg.q_dim,), dtype)
        p["k_b"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["v_b"] = jnp.zeros((cfg.kv_dim,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
    return p


def _init_block(key, cfg: ModelConfig, kind: str, dtype):
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"norm": L.init_norm(cfg)}
    if kind in (FULL_ATTN, LOCAL_ATTN):
        p["attn"] = _init_attn(ks[0], cfg, dtype)
    elif kind == SSM:
        p["ssm"] = init_ssm(ks[0], cfg, dtype)
    elif kind == RGLRU:
        p["rglru"] = init_rglru(ks[0], cfg, dtype)
    if cfg.post_block_norm:
        p["post_norm"] = L.init_norm(cfg)
    if cfg.d_ff > 0 and kind != SSM:
        p["mlp_norm"] = L.init_norm(cfg)
        if cfg.is_moe:
            p["moe"] = init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg, dtype)
        if cfg.post_block_norm:
            p["post_mlp_norm"] = L.init_norm(cfg)
    return p


def init_params(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_final, *stage_keys = jax.random.split(key, 2 + len(stages_of(cfg)))
    params: Dict[str, Any] = {
        "embed": L.init_embed(k_embed, cfg, dtype),
        "final_norm": L.init_norm(cfg),
        "stages": [],
    }
    for (kinds, n_rep), sk in zip(stages_of(cfg), stage_keys):
        groups = []
        for r, rk in enumerate(jax.random.split(sk, n_rep)):
            bkeys = jax.random.split(rk, len(kinds))
            groups.append({"blocks": tuple(_init_block(bk, cfg, kind, dtype)
                                           for bk, kind in zip(bkeys, kinds))})
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *groups) \
            if n_rep > 1 else jax.tree.map(lambda x: x[None], groups[0])
        params["stages"].append(stacked)
    return params


def param_specs(cfg: ModelConfig, shd: ShardCtx) -> Dict:
    """PartitionSpecs for the param pytree (tensor-parallel over model axis)."""
    m = shd.model_axis
    msize = shd.mesh.shape[m] if shd.mesh is not None else 1

    def attn_spec():
        kv = m if cfg.num_kv_heads * cfg.head_dim % max(msize, 1) == 0 \
            and cfg.num_kv_heads % msize == 0 else None
        s = {"q": P(None, None, m), "k": P(None, None, kv), "v": P(None, None, kv),
             "o": P(None, m, None)}
        if cfg.qkv_bias:
            s.update({"q_b": P(None, m), "k_b": P(None, kv), "v_b": P(None, kv)})
        if cfg.qk_norm:
            s.update({"q_norm": P(None, None), "k_norm": P(None, None)})
        return s

    def mlp_spec():
        s = {"down": P(None, m, None)}
        if cfg.glu:
            s.update({"gate": P(None, None, m), "up": P(None, None, m)})
        else:
            s.update({"up": P(None, None, m), "up_b": P(None, m),
                      "down_b": P(None, None)})
        return s

    def moe_spec():
        e = m if cfg.num_experts % max(msize, 1) == 0 else None
        ffm = None if e == m else m
        s = {"router": P(None, None, None),
             "down": P(None, e, ffm, None)}
        if cfg.glu:
            s.update({"gate": P(None, e, None, ffm), "up": P(None, e, None, ffm)})
        else:
            s.update({"up": P(None, e, None, ffm)})
        return s

    def norm_spec(p):
        return jax.tree.map(lambda _: P(None, None), p)

    def ssm_spec():
        return {"in_proj": P(None, None, m), "conv_w": P(None, None, None),
                "conv_b": P(None, None), "A_log": P(None, None), "D": P(None, None),
                "dt_bias": P(None, None), "norm": P(None, m),
                "out_proj": P(None, m, None)}

    def rglru_spec():
        return {"in_x": P(None, None, m), "in_gate": P(None, None, m),
                "conv_w": P(None, None, m), "conv_b": P(None, m),
                "lam": P(None, m), "rg_w": P(None, m), "ig_w": P(None, m),
                "out": P(None, m, None)}

    def block_spec(kind, bp):
        s: Dict[str, Any] = {"norm": norm_spec(bp["norm"])}
        if kind in (FULL_ATTN, LOCAL_ATTN):
            s["attn"] = attn_spec()
        elif kind == SSM:
            s["ssm"] = ssm_spec()
        elif kind == RGLRU:
            s["rglru"] = rglru_spec()
        if "post_norm" in bp:
            s["post_norm"] = norm_spec(bp["post_norm"])
        if "mlp_norm" in bp:
            s["mlp_norm"] = norm_spec(bp["mlp_norm"])
            if cfg.is_moe:
                s["moe"] = moe_spec()
            else:
                s["mlp"] = mlp_spec()
            if "post_mlp_norm" in bp:
                s["post_mlp_norm"] = norm_spec(bp["post_mlp_norm"])
        return s

    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    embed_s = {"embedding": P(m, None)}
    if "lm_head" in shapes["embed"]:
        embed_s["lm_head"] = P(None, m)
    if "prefix_proj" in shapes["embed"]:
        embed_s["prefix_proj"] = P(None, None)
    specs = {"embed": embed_s, "final_norm": norm_spec(shapes["final_norm"]),
             "stages": []}
    for (kinds, n_rep), sp in zip(stages_of(cfg), shapes["stages"]):
        specs["stages"].append(
            {"blocks": tuple(block_spec(k, b) for k, b in zip(kinds, sp["blocks"]))})
    return specs


# -- block application ----------------------------------------------------------------

def _apply_attn(cfg: ModelConfig, p, x, kind, *, mode, positions, cache, pos,
                shd, slot=None, length=None, page_table=None):
    B, S, _ = x.shape
    q = x @ p["attn"]["q"]
    k = x @ p["attn"]["k"]
    v = x @ p["attn"]["v"]
    if cfg.qkv_bias:
        q, k, v = q + p["attn"]["q_b"], k + p["attn"]["k_b"], v + p["attn"]["v_b"]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if not (mode == "decode" and shd.kv_seq_sharded):
        q = shd.cs(q, "b", None, "m", None)
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["attn"]["q_norm"], cfg.norm_eps)
        k = L.rmsnorm(k, p["attn"]["k_norm"], cfg.norm_eps)
    if cfg.pos_embedding == "rope":
        inv, rot = L.rope_freqs(cfg)
        q = L.apply_rope(q, positions, inv, rot)
        k = L.apply_rope(k, positions, inv, rot)

    window = cfg.window if kind == LOCAL_ATTN else 0
    new_cache = None
    if mode == "decode" and KV.is_paged(cache):
        # paged pool: scatter the new token by page table, gather the
        # ctx-bucketed page chain back as a dense (B, n_pages*ps) context
        pos_v = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(pos, jnp.int32)),
                                 (B,))
        new_cache = KV.paged_cache_write_decode(cache, k, v, pos_v, page_table)
        k_att, v_att = KV.paged_cache_kv_arrays(new_cache, page_table, q.dtype)
        # serving mesh: the pool's page axis is data-sharded while the
        # gathered per-row context is batch-sharded — constrain the gather
        # output so GSPMD routes pages once instead of replicating the pool
        # into every shard's gather (guidance only; rows are independent, so
        # placement cannot change the bits)
        k_att = shd.cs(k_att, "b", None, None, None)
        v_att = shd.cs(v_att, "b", None, None, None)
        k_pos = jnp.broadcast_to(
            KV.paged_key_positions(k_att.shape[1], pos_v + 1),
            (B, k_att.shape[1]))
        out = attention(q, k_att, v_att, positions, k_pos, window=window,
                        softcap=cfg.attn_softcap, scale=cfg.attn_scale,
                        unroll=cfg.unroll_scans)
    elif mode == "decode":
        new_cache = KV.cache_write_decode(cache, k, v, pos)
        k_full, v_full = KV.cache_kv_arrays(new_cache, q.dtype)
        k_pos = KV.cache_key_positions(new_cache, pos, B)
        buf_len = k_full.shape[1]
        if window == 0 and buf_len < cfg.max_seq:
            window = buf_len          # long-context ring buffer on full attn
        k_att = k_full
        v_att = v_full
        if shd.kv_seq_sharded and cfg.num_heads != cfg.num_kv_heads:
            # pre-expand GQA and pin the expanded KV to the cache's sequence
            # sharding; otherwise the o-projection's head sharding propagates
            # backwards and XLA all-gathers the whole cache per step.
            rep = cfg.num_heads // cfg.num_kv_heads
            k_att = shd.cs(jnp.repeat(k_att, rep, axis=2), "b", "m", None, None)
            v_att = shd.cs(jnp.repeat(v_att, rep, axis=2), "b", "m", None, None)
        out = attention(q, k_att, v_att,
                        positions, k_pos, window=window,
                        softcap=cfg.attn_softcap, scale=cfg.attn_scale,
                        unroll=cfg.unroll_scans)
        if shd.kv_seq_sharded:
            out = shd.cs(out, "b", None, None, None)
    elif mode == "chunk":
        # chunked prefill: attend to [cached past context | raw current
        # chunk], then write the chunk into the cache for later chunks and
        # decode.  The past is read BEFORE the write so the current chunk
        # contributes raw (unquantized, uncast) K/V, matching one-shot
        # prefill; ``pos`` is the chunk's start position (traced scalar).
        start = jnp.asarray(pos, jnp.int32)
        if KV.is_paged(cache):
            pk, pv = KV.paged_cache_kv_arrays(cache, page_table, q.dtype)
            past_pos = KV.paged_key_positions(pk.shape[1], start)
            new_cache = KV.paged_cache_write_chunk(cache, k, v, page_table[0],
                                                   start, length)
        else:
            pk, pv = KV.cache_row_kv_arrays(cache, slot, q.dtype)
            past_pos = KV.ring_slot_positions(pk.shape[1], start)[None]
            new_cache = KV.cache_write_chunk_slot(cache, k, v, slot, start,
                                                  length)
            if window == 0 and pk.shape[1] < cfg.max_seq:
                window = pk.shape[1]  # long-context ring: bounded lookback
        i = jnp.arange(S, dtype=jnp.int32)
        cur_pos = jnp.where(i[None, :] < jnp.asarray(length, jnp.int32),
                            positions, -1)
        out = attention(q, jnp.concatenate([pk, k.astype(pk.dtype)], axis=1),
                        jnp.concatenate([pv, v.astype(pv.dtype)], axis=1),
                        positions, jnp.concatenate([past_pos, cur_pos], axis=1),
                        window=window, softcap=cfg.attn_softcap,
                        scale=cfg.attn_scale, unroll=cfg.unroll_scans)
    else:
        if mode == "prefill":
            if slot is not None and KV.is_paged(cache):
                # slot-native one-shot prefill into this stream's page chain
                # (bucket pads >= length are dropped, not masked: their pages
                # may not be allocated)
                new_cache = KV.paged_cache_write_chunk(
                    cache, k, v, page_table[0], jnp.asarray(0, jnp.int32),
                    S if length is None else length)
            elif slot is not None:
                # slot-native: write this prompt's K/V into one row of the
                # batch cache; other rows flow through untouched.
                new_cache = KV.cache_write_prefill_slot(cache, k, v, slot)
            else:
                new_cache = KV.cache_write_prefill(cache, k, v)
            if not KV.is_paged(new_cache):
                buf_len = new_cache["k"].shape[1]
                if window == 0 and buf_len < S:
                    window = buf_len
        out = attention(q, k, v, positions, positions, window=window,
                        softcap=cfg.attn_softcap, scale=cfg.attn_scale,
                        unroll=cfg.unroll_scans)
    if not (mode == "decode" and shd.kv_seq_sharded):
        out = shd.cs(out, "b", None, "m", None)
    out = out.reshape(B, S, cfg.q_dim) @ p["attn"]["o"]
    return out, new_cache


def _freeze_inactive(new_cache, cache, active):
    """Recurrent decode steps advance state for every batch row; rows outside
    the active set (retired, or mid-chunked-prefill) must keep their cached
    state — unlike K/V buffers there is no position masking to hide a bogus
    update, so an unfrozen mid-prefill row would resume its next chunk from
    state polluted by other streams' decode blocks."""
    if active is None:
        return new_cache
    return jax.tree.map(
        lambda n, o: jnp.where(
            active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
        new_cache, cache)


def _apply_block(cfg: ModelConfig, kind: str, p, x, *, mode, positions,
                 cache, pos, shd, slot=None, length=None, valid=None,
                 page_table=None, active=None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(cfg, p["norm"], x)
    new_cache = None
    if kind in (FULL_ATTN, LOCAL_ATTN):
        mix, new_cache = _apply_attn(cfg, p, h, kind, mode=mode,
                                     positions=positions, cache=cache,
                                     pos=pos, shd=shd, slot=slot,
                                     length=length, page_table=page_table)
    elif kind == SSM:
        if mode == "decode":
            mix, new_cache = ssm_decode_step(cfg, p["ssm"], h, cache)
            new_cache = _freeze_inactive(new_cache, cache, active)
        elif mode in ("prefill", "chunk"):
            row = KV.state_row_slot(cache, slot) if mode == "chunk" else None
            mix, new_cache = ssm_forward(cfg, p["ssm"], h, return_state=True,
                                         cache=row, length=length)
            if slot is not None:
                new_cache = KV.state_write_slot(cache, new_cache, slot)
        else:
            mix = ssm_forward(cfg, p["ssm"], h)
    elif kind == RGLRU:
        if mode == "decode":
            mix, new_cache = rglru_decode_step(cfg, p["rglru"], h, cache)
            new_cache = _freeze_inactive(new_cache, cache, active)
        elif mode in ("prefill", "chunk"):
            row = KV.state_row_slot(cache, slot) if mode == "chunk" else None
            mix, new_cache = rglru_forward(cfg, p["rglru"], h,
                                           return_state=True,
                                           cache=row, length=length)
            if slot is not None:
                new_cache = KV.state_write_slot(cache, new_cache, slot)
        else:
            mix = rglru_forward(cfg, p["rglru"], h)
    else:
        raise ValueError(kind)
    if new_cache is not None and cache is not None:
        # match the caller-allocated buffer dtypes (e.g. f32 test caches)
        new_cache = jax.tree.map(lambda n, o: n.astype(o.dtype), new_cache, cache)
    if cfg.post_block_norm:
        mix = L.apply_norm(cfg, p["post_norm"], mix)
    x = x + mix
    x = shd.cs(x, "b", None, None)

    if cfg.d_ff > 0 and kind != SSM:
        h = L.apply_norm(cfg, p["mlp_norm"], x)
        if cfg.is_moe:
            m, a = apply_moe(cfg, p["moe"], h, shd, valid)
            aux = aux + a
        else:
            m = L.apply_mlp(cfg, p["mlp"], h)
        if cfg.post_block_norm:
            m = L.apply_norm(cfg, p["post_mlp_norm"], m)
        x = x + m
        x = shd.cs(x, "b", None, None)
    return x, new_cache, aux


# -- stage execution -------------------------------------------------------------------

def _run_stages(cfg: ModelConfig, params, x, *, mode, positions, caches, pos,
                shd: ShardCtx, remat: bool, slot=None, length=None,
                valid=None, page_table=None, active=None):
    """caches: list (per stage) of stacked per-group caches or None."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for si, ((kinds, n_rep), sp) in enumerate(zip(stages_of(cfg), params["stages"])):
        stage_cache = caches[si] if caches is not None else None

        def group_fn(x, group_p, group_c):
            auxs = jnp.zeros((), jnp.float32)
            outs = []
            for j, kind in enumerate(kinds):
                c = group_c[j] if group_c is not None else None
                x, nc, a = _apply_block(cfg, kind, group_p["blocks"][j], x,
                                        mode=mode, positions=positions,
                                        cache=c, pos=pos, shd=shd, slot=slot,
                                        length=length, valid=valid,
                                        page_table=page_table, active=active)
                auxs = auxs + a
                outs.append(nc)
            return x, tuple(outs), auxs

        if remat:
            group_fn = jax.checkpoint(group_fn)

        if stage_cache is not None:
            def body(carry, xs):
                x, aux = carry
                gp, gc = xs
                x, ncache, a = group_fn(x, gp, gc)
                return (x, aux + a), ncache

            (x, aux_total), ys = jax.lax.scan(body, (x, aux_total),
                                              (sp, stage_cache),
                                              unroll=cfg.unroll_scans)
            new_caches.append(ys)
        else:
            def body(carry, gp):
                x, aux = carry
                x, ncache, a = group_fn(x, gp, None)
                return (x, aux + a), ncache

            (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), sp,
                                              unroll=cfg.unroll_scans)
            new_caches.append(ys if mode == "prefill" else None)
    return x, new_caches, aux_total


# -- embedding helpers -------------------------------------------------------------------

def _embed_inputs(cfg: ModelConfig, params, tokens, prefix_embeds, shd, start_pos=0):
    x = L.embed_tokens(cfg, params["embed"], tokens)
    if prefix_embeds is not None:
        pe = prefix_embeds.astype(x.dtype) @ params["embed"]["prefix_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    B, S, _ = x.shape
    positions = start_pos + jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos_embedding == "sincos":
        x = x + L.sincos_embedding(positions, cfg.d_model).astype(x.dtype)
    x = shd.cs(x, "b", None, None)
    return x, positions


# -- public API -----------------------------------------------------------------------------

def forward_train(params, cfg: ModelConfig, tokens, prefix_embeds=None,
                  shd: ShardCtx = NOSHARD, remat: bool = True):
    """tokens (B,S) -> logits (B,S_total,vocab), aux_loss."""
    x, positions = _embed_inputs(cfg, params, tokens, prefix_embeds, shd)
    x, _, aux = _run_stages(cfg, params, x, mode="train", positions=positions,
                            caches=None, pos=None, shd=shd, remat=remat)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], x)
    logits = shd.cs(logits, "b", None, "m")
    return logits, aux


def _hidden_train(params, cfg: ModelConfig, tokens, prefix_embeds, shd, remat):
    x, positions = _embed_inputs(cfg, params, tokens, prefix_embeds, shd)
    x, _, aux = _run_stages(cfg, params, x, mode="train", positions=positions,
                            caches=None, pos=None, shd=shd, remat=remat)
    return L.apply_norm(cfg, params["final_norm"], x), aux


def _ce_block(cfg: ModelConfig, params, h, tgt, shd, valid=None):
    """h (B,T,d), tgt (B,T) -> (sum_ce, count). Logits live only per block."""
    logits = L.unembed(cfg, params["embed"], h)
    logits = shd.cs(logits, "b", None, "m")
    pred = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(pred, axis=-1)
    onehot = jax.nn.one_hot(tgt, cfg.vocab_size, dtype=jnp.bfloat16)
    gold = jnp.sum(pred * onehot, axis=-1)
    ce = lse - gold
    if valid is not None:
        ce = ce * valid
    return jnp.sum(ce), lse.size


def loss_fn(params, cfg: ModelConfig, batch, shd: ShardCtx = NOSHARD,
            remat: bool = True, ce_chunk: int = 1024):
    """batch: {tokens (B,S), [prefix_embeds]}; next-token CE over token span.

    The unembed + cross-entropy is computed in sequence chunks under remat so
    the (B, S, vocab) logits tensor is never materialized (vocab up to 256k).
    """
    tokens = batch["tokens"]
    h, aux = _hidden_train(params, cfg, tokens, batch.get("prefix_embeds"),
                           shd, remat)
    Pn = h.shape[1] - tokens.shape[1]
    h = h[:, Pn:-1]
    tgt = tokens[:, 1:]
    T = h.shape[1]
    if T <= ce_chunk:
        ce_sum, n = _ce_block(cfg, params, h, tgt, shd)
        ce = ce_sum / n
    else:
        # pad T up to a chunk multiple; padded positions are masked out
        nc = -(-T // ce_chunk)
        pad = nc * ce_chunk - T
        B = h.shape[0]
        hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        tp = jnp.pad(tgt, ((0, 0), (0, pad)))
        vp = jnp.pad(jnp.ones((B, T), jnp.float32), ((0, 0), (0, pad)))
        hc = hp.reshape(B, nc, ce_chunk, -1).swapaxes(0, 1)
        tc = tp.reshape(B, nc, ce_chunk).swapaxes(0, 1)
        vc = vp.reshape(B, nc, ce_chunk).swapaxes(0, 1)

        @jax.checkpoint
        def body(acc, xs):
            hi, ti, vi = xs
            s, n = _ce_block(cfg, params, hi, ti, shd, vi)
            return acc + s, None

        ce_sum, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                 (hc, tc, vc), unroll=cfg.unroll_scans)
        ce = ce_sum / (T * B)
    return ce + cfg.router_aux_loss * aux, {"ce": ce, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               long_context: bool = False, dtype=jnp.bfloat16,
               paged_pool=None) -> List:
    """Stacked cache pytree parallel to params['stages'].

    ``paged_pool=(num_pages, page_size)`` switches every *full-length*
    attention buffer (the ones whose size gates concurrent-stream capacity)
    to a shared paged pool addressed through a page table (see
    ``kvcache.init_paged_attn_cache``); bounded buffers (sliding window /
    long-context rings) and recurrent states keep the dense batch layout.
    """
    caches = []
    for kinds, n_rep in stages_of(cfg):
        group = []
        for k in kinds:
            if (paged_pool is not None and k in (FULL_ATTN, LOCAL_ATTN)
                    and KV.attn_buffer_len(cfg, k, max_len,
                                           long_context) == max_len):
                group.append(KV.init_paged_attn_cache(cfg, *paged_pool, dtype))
            else:
                group.append(KV.init_block_cache(cfg, k, batch, max_len,
                                                 long_context, dtype))
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_rep,) + x.shape),
            tuple(group))
        caches.append(stacked)
    return caches


def prefill(params, cfg: ModelConfig, tokens, caches, prefix_embeds=None,
            shd: ShardCtx = NOSHARD):
    """Run the prompt, fill caches. Returns (last_logits (B,vocab), caches, next_pos)."""
    x, positions = _embed_inputs(cfg, params, tokens, prefix_embeds, shd)
    x, new_caches, _ = _run_stages(cfg, params, x, mode="prefill",
                                   positions=positions, caches=caches, pos=None,
                                   shd=shd, remat=False)
    last = L.apply_norm(cfg, params["final_norm"], x[:, -1:])
    logits = L.unembed(cfg, params["embed"], last)[:, 0]
    logits = shd.cs(logits, "b", "m")
    return logits, new_caches, x.shape[1]


def prefill_into_slot(params, cfg: ModelConfig, tokens, length, caches, slot,
                      shd: ShardCtx = NOSHARD, page_table=None):
    """Bucket-padded prefill of ONE prompt written into row ``slot`` of the
    shared batch caches, as a single jittable computation.

    ``tokens`` is (1, S_pad): the prompt right-padded to a static bucket
    length (a small set of buckets bounds compile count); ``length`` is the
    true prompt length (traced scalar); ``slot`` is the traced batch-row
    index.  K/V (and SSM/RG-LRU states) are written directly into the batch
    cache row via ``dynamic_update_slice`` — no fresh per-request cache is
    allocated and no full-batch splice happens on the host, so the caller can
    donate ``caches`` and XLA updates them in place.  Pad positions >= length
    hold garbage K/V that the position mask hides until the decode loop
    overwrites them (see ``kvcache.cache_write_prefill_slot``).

    Requires S_pad <= every dense attention buffer length (asserted at trace
    time); longer prompts go through ``prefill_chunk_into_slot`` (or the
    reference ``prefill`` path).  Pad tokens are masked out of expert-capacity
    competition (MoE) and out of the recurrent-state updates (SSM / RG-LRU),
    so a bucketed prompt matches its unpadded reference.  ``page_table``
    ((1, n_pages) row) addresses the paged K/V pools when the caches were
    built with ``init_cache(..., paged_pool=...)``.

    Returns (last_logits (1, vocab), caches, next_pos == length).
    """
    x, positions = _embed_inputs(cfg, params, tokens, None, shd)
    valid = (jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
             < jnp.asarray(length, jnp.int32))
    x, new_caches, _ = _run_stages(cfg, params, x, mode="prefill",
                                   positions=positions, caches=caches,
                                   pos=None, shd=shd, remat=False, slot=slot,
                                   length=length, valid=valid,
                                   page_table=page_table)
    last = jax.lax.dynamic_slice_in_dim(x, jnp.asarray(length, jnp.int32) - 1,
                                        1, axis=1)
    last = L.apply_norm(cfg, params["final_norm"], last)
    logits = L.unembed(cfg, params["embed"], last)[:, 0]
    logits = shd.cs(logits, "b", "m")
    return logits, new_caches, length


def prefill_chunk_into_slot(params, cfg: ModelConfig, tokens, start, length,
                            caches, slot, shd: ShardCtx = NOSHARD,
                            page_table=None):
    """One *chunk* of a chunked prefill: process ``tokens`` (1, S_pad) at
    absolute positions ``start..start+S_pad-1`` (``length`` of them valid)
    for the stream in row ``slot``, attending to all context this stream has
    already written (earlier chunks live in its cache row / page chain).

    Chunks must be fed in position order; attention reads the cached past
    (dense ring row or gathered page chain) and the raw current chunk, then
    writes the chunk's K/V — pads are *dropped*, not masked, because chunk
    writes may wrap a ring buffer onto valid earlier context.  SSM / RG-LRU
    states resume from the cached row state and are written back, so hybrid
    archs chunk exactly like attention-only ones.  Used by the serving
    engine to admit prompts longer than the smallest attention buffer across
    successive decode blocks instead of falling back to the eager reference
    prefill.

    Returns (last_logits (1, vocab), caches): logits at position
    ``start+length-1`` — only the final chunk's logits seed decoding.
    """
    x, positions = _embed_inputs(cfg, params, tokens, None, shd,
                                 start_pos=jnp.asarray(start, jnp.int32))
    valid = (jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
             < jnp.asarray(length, jnp.int32))
    x, new_caches, _ = _run_stages(cfg, params, x, mode="chunk",
                                   positions=positions, caches=caches,
                                   pos=start, shd=shd, remat=False, slot=slot,
                                   length=length, valid=valid,
                                   page_table=page_table)
    last = jax.lax.dynamic_slice_in_dim(x, jnp.asarray(length, jnp.int32) - 1,
                                        1, axis=1)
    last = L.apply_norm(cfg, params["final_norm"], last)
    logits = L.unembed(cfg, params["embed"], last)[:, 0]
    logits = shd.cs(logits, "b", "m")
    return logits, new_caches


def sample_tokens(logits, temperature: float = 0.0, key=None):
    """On-device sampling: (B, vocab) logits -> (B,) int32 token ids.

    ``temperature <= 0`` (or no key) is greedy argmax; otherwise categorical
    sampling at the given temperature.  Kept inside the jitted serving step so
    the steady-state decode loop never ships logits to the host.  The scalar
    (batch-global) legacy surface — the serving engines sample per slot via
    ``sample_tokens_batched``.
    """
    if temperature > 0.0 and key is not None:
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_tokens_batched(logits, temps, top_k, top_p, keys):
    """Per-row vectorized sampling: (B, vocab) logits -> (B,) int32 tokens.

    Each batch row carries its own sampling lane, so heterogeneous requests
    (greedy code completion next to creative-writing nucleus sampling) share
    one jitted decode step with no static sampling arguments:

    * ``temps`` (B,) float: ``0`` rows are greedy argmax — bit-identical to
      ``jnp.argmax`` — all other rows sample at their own temperature.
    * ``top_k`` (B,) int: keep only the k highest logits per row (``<= 0``
      or ``>= vocab`` disables the filter for that row).
    * ``top_p`` (B,) float: nucleus filtering — keep the smallest prefix of
      the (top-k-filtered, temperature-scaled) distribution whose
      cumulative probability reaches ``top_p`` (``>= 1.0`` disables; the
      disabled filters leave the logits untouched, so ``top_k=vocab,
      top_p=1.0`` reduces *exactly* to plain temperature sampling).
    * ``keys`` (B, 2) uint32: one PRNG key per row, consumed whole for this
      draw — callers derive one subkey per draw (the serving engine folds
      the token's sequence position into the stream's base lane), keeping
      rows independent: row i's draw never reads row j's key or logits.

    Fully on-device (one sort per draw, no host syncs), safe under
    ``lax.scan``.
    """
    V = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    temps = jnp.asarray(temps, jnp.float32)
    safe_t = jnp.where(temps > 0.0, temps, 1.0)
    scaled = lg / safe_t[:, None]
    # rank rows once (descending); both filters are masks in sorted space
    order = jnp.argsort(scaled, axis=-1)[:, ::-1]
    s = jnp.take_along_axis(scaled, order, axis=-1)
    ranks = jnp.arange(V, dtype=jnp.int32)[None, :]
    k = jnp.asarray(top_k, jnp.int32)
    k_eff = jnp.where((k <= 0) | (k >= V), V, k)
    keep = ranks < k_eff[:, None]
    # nucleus mass over the top-k survivors; cum_prev is the mass *before*
    # each token, so rank 0 is always kept (the filter can never mask the
    # entire row) and exactly the smallest covering prefix survives
    probs = jax.nn.softmax(jnp.where(keep, s, -jnp.inf), axis=-1)
    cum_prev = jnp.cumsum(probs, axis=-1) - probs
    p = jnp.asarray(top_p, jnp.float32)
    keep &= (cum_prev < p[:, None]) | (p[:, None] >= 1.0)
    inv = jnp.argsort(order, axis=-1)
    keep = jnp.take_along_axis(keep, inv, axis=-1)
    filtered = jnp.where(keep, scaled, -jnp.inf)
    draw = jax.vmap(
        lambda kk, row: jax.random.categorical(kk, row))(keys, filtered)
    return jnp.where(temps > 0.0, draw.astype(jnp.int32), greedy_tok)


def decode_step(params, cfg: ModelConfig, tokens, caches, pos,
                shd: ShardCtx = NOSHARD, page_table=None, active=None):
    """tokens (B,1) -> (logits (B,vocab), caches).

    ``pos`` is either a traced scalar (all rows decode at one shared stream
    position — the lockstep path used by training-style eval) or a (B,) int32
    vector of per-slot positions (slot-native serving: each row attends to its
    own context length, RoPE/masks/cache-writes are per-row).

    ``page_table`` ((B, n_pages) int32) must be passed when ``caches`` hold
    paged pools (``init_cache(..., paged_pool=...)``): each row's K/V write
    and gather go through its page chain, ctx-bounded by the caller-sliced
    table width.

    ``active`` ((B,) bool) freezes inactive rows' *recurrent* (SSM/RG-LRU)
    states: K/V writes of inactive rows are hidden by position masking, but
    recurrent state has no positions, so without the mask a mid-chunked-
    prefill row would be polluted by other streams' decode blocks.
    """
    B = tokens.shape[0]
    if shd.mesh is not None:
        # one-hot matmul lookup: with a vocab-sharded table this lowers to a
        # sharded matmul + tiny psum instead of all-gathering the table
        # (gemma2's table alone is 1.8 GB) on every decode step.
        oh = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=params["embed"]["embedding"].dtype)
        x = oh @ params["embed"]["embedding"]
        if cfg.embed_scale:
            x = x * jnp.asarray(jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32)), x.dtype)
    else:
        x = L.embed_tokens(cfg, params["embed"], tokens)
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(pos.reshape(-1, 1), (B, 1))
    if cfg.pos_embedding == "sincos":
        x = x + L.sincos_embedding(positions, cfg.d_model).astype(x.dtype)
    x = shd.cs(x, "b", None, None)
    x, new_caches, _ = _run_stages(cfg, params, x, mode="decode",
                                   positions=positions, caches=caches, pos=pos,
                                   shd=shd, remat=False,
                                   page_table=page_table, active=active)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], x)[:, 0]
    logits = shd.cs(logits, "b", "m")
    return logits, new_caches
