"""Shared layer primitives: norms, activations, RoPE, MLPs, embeddings."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig


def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(dtype)


# -- norms ---------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (scale.astype(jnp.float32))
    return y.astype(dt)


def layernorm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(dt)


def init_norm(cfg: ModelConfig, dim=None):
    dim = dim or cfg.d_model
    if cfg.norm == "rms":
        return {"scale": jnp.ones((dim,), jnp.float32)}
    return {"scale": jnp.ones((dim,), jnp.float32), "bias": jnp.zeros((dim,), jnp.float32)}


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rms":
        return rmsnorm(x, p["scale"], cfg.norm_eps)
    return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)


# -- activations -----------------------------------------------------------------

def activation(cfg: ModelConfig, x):
    if cfg.act == "silu":
        return jax.nn.silu(x)
    if cfg.act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(cfg.act)


# -- RoPE -------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig):
    rot = int(cfg.head_dim * cfg.rotary_frac)
    rot -= rot % 2
    inv = 1.0 / (cfg.rope_theta ** (np.arange(0, rot, 2, dtype=np.float32) / rot))
    return jnp.asarray(inv), rot


def apply_rope(x, positions, inv_freq, rot):
    """x: (B,S,H,hd); positions: (B,S) int32. Rotates first `rot` dims (neox)."""
    dt = x.dtype
    xr, xp = x[..., :rot], x[..., rot:]
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # (B,S,rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out, xp.astype(jnp.float32)], axis=-1).astype(dt)


def sincos_embedding(positions, dim):
    """Sinusoidal absolute positional embedding (musicgen). positions (B,S)."""
    half = dim // 2
    freq = np.exp(-math.log(10_000.0) * np.arange(half, dtype=np.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * jnp.asarray(freq)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# -- MLP ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"down": dense_init(k3, (ff, d), dtype=dtype)}
    if cfg.glu:
        p["gate"] = dense_init(k1, (d, ff), dtype=dtype)
        p["up"] = dense_init(k2, (d, ff), dtype=dtype)
    else:
        p["up"] = dense_init(k2, (d, ff), dtype=dtype)
        p["up_b"] = jnp.zeros((ff,), dtype)
        p["down_b"] = jnp.zeros((d,), dtype)
    return p


def apply_mlp(cfg: ModelConfig, p, x):
    if cfg.glu:
        g = activation(cfg, x @ p["gate"])
        return (g * (x @ p["up"])) @ p["down"]
    h = activation(cfg, x @ p["up"] + p["up_b"])
    return h @ p["down"] + p["down_b"]


# -- embedding / unembedding ---------------------------------------------------------

def init_embed(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    p = {"embedding": dense_init(k1, (cfg.vocab_size, cfg.d_model), in_axis=-1, dtype=dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), dtype=dtype)
    if cfg.num_prefix_embeds:
        p["prefix_proj"] = dense_init(k2, (cfg.d_model, cfg.d_model), dtype=dtype)
    return p


def embed_tokens(cfg: ModelConfig, p, tokens):
    x = jnp.take(p["embedding"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(cfg: ModelConfig, p, x):
    if cfg.tie_embeddings:
        logits = x @ p["embedding"].T
    else:
        logits = x @ p["lm_head"]
    if cfg.final_softcap:
        c = cfg.final_softcap
        logits = jnp.tanh(logits.astype(jnp.float32) / c) * c
    return logits
