"""GQA attention (XLA reference path) used for training, prefill and decode.

The Pallas kernels in ``repro.kernels`` implement the same math with explicit
VMEM tiling for the TPU target; this module is the shardable XLA path used by
the multi-pod dry-run and the CPU smoke tests.  Long sequences are processed
in query chunks (flash-style streaming over the key dimension is left to the
kernel; chunking bounds the materialized score block).

Positions are *per batch row*: ``q_pos``/``k_pos`` are (B, S) and every mask
(causal, sliding window, ring-buffer validity via negative ``k_pos``) is
evaluated row-wise.  The slot-native serving engine relies on this: a batch
mixes streams at different decode positions, and each row must attend to its
own context — never reduce positions over the batch dimension here.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def _mask(q_pos, k_pos, window: int):
    """(B,Sq),(B,Sk) -> bool (B,Sq,Sk). Causal + optional sliding window.

    Slots with negative k_pos (unfilled ring-buffer entries) are masked out.
    """
    m = k_pos[:, None, :] <= q_pos[:, :, None]
    m &= k_pos[:, None, :] >= 0
    if window:
        m &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    return m


def _attend_block(q, k, v, q_pos, k_pos, *, window, softcap, scale, skip_blocks=False):
    """q (B,Sq,H,hd), k/v (B,Sk,H,hd) — H already GQA-expanded."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    m = _mask(q_pos, k_pos, window)[:, None]
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with every key masked (can happen for padded ring slots) -> zeros
    any_valid = jnp.any(m, axis=-1, keepdims=True)
    p = jnp.where(any_valid, p, 0.0)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def attention(
    q: jax.Array,            # (B, Sq, Hq, hd)
    k: jax.Array,            # (B, Sk, KH, hd)
    v: jax.Array,            # (B, Sk, KH, hd)
    q_pos: jax.Array,        # (B, Sq) int32 absolute positions
    k_pos: jax.Array,        # (B, Sk) int32 absolute positions (-1 = invalid)
    *,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    q_chunk: int = 1024,
    unroll: bool = False,
) -> jax.Array:
    B, Sq, Hq, hd = q.shape
    KH = k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    if Hq != KH:
        rep = Hq // KH
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    if Sq <= q_chunk or Sq % q_chunk != 0:
        return _attend_block(q, k, v, q_pos, k_pos,
                             window=window, softcap=softcap, scale=scale)

    nc = Sq // q_chunk
    qc = q.reshape(B, nc, q_chunk, Hq, hd).swapaxes(0, 1)        # (nc,B,qc,H,hd)
    pc = q_pos.reshape(B, nc, q_chunk).swapaxes(0, 1)            # (nc,B,qc)

    @jax.checkpoint
    def body(_, xs):
        qi, pi = xs
        o = _attend_block(qi, k, v, pi, k_pos,
                          window=window, softcap=softcap, scale=scale)
        return _, o

    _, out = jax.lax.scan(body, None, (qc, pc), unroll=unroll)
    return out.swapaxes(0, 1).reshape(B, Sq, Hq, hd)
