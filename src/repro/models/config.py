"""Model configuration covering every assigned architecture family.

A single ``ModelConfig`` dataclass parameterizes dense GQA transformers,
MoE (token-choice top-k), Mamba2 SSD, RG-LRU hybrids, and the audio/VLM
decoder backbones.  Layer heterogeneity (gemma2's local/global alternation,
recurrentgemma's rec,rec,attn pattern) is expressed as a *block pattern*
cycled over the depth; the transformer stacks identical pattern-groups and
scans over them (see transformer.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# Block kinds -----------------------------------------------------------------
FULL_ATTN = "full"      # causal full attention
LOCAL_ATTN = "local"    # causal sliding-window attention (cfg.window)
SSM = "ssm"             # Mamba2 SSD mixer (attention-free)
RGLRU = "rglru"         # RG-LRU recurrent mixer (recurrentgemma)

VALID_KINDS = (FULL_ATTN, LOCAL_ATTN, SSM, RGLRU)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                       # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int                            # per-expert width for MoE; 0 = no MLP
    vocab_size: int

    # attention ---------------------------------------------------------------
    block_pattern: Tuple[str, ...] = (FULL_ATTN,)
    window: int = 0                      # sliding-window size for LOCAL_ATTN
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rotary_frac: float = 1.0             # fraction of head_dim rotated (chatglm: 0.5)
    pos_embedding: str = "rope"          # rope | sincos (musicgen) | none
    attn_softcap: float = 0.0            # gemma2: 50.0
    final_softcap: float = 0.0           # gemma2: 30.0
    attn_scale: Optional[float] = None   # default 1/sqrt(head_dim)

    # block/MLP ---------------------------------------------------------------
    act: str = "silu"                    # silu | gelu
    glu: bool = True                     # gated MLP (SwiGLU/GeGLU) vs plain
    norm: str = "rms"                    # rms | layer
    norm_eps: float = 1e-6
    post_block_norm: bool = False        # gemma2 sandwich norms
    embed_scale: bool = False            # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = False

    # MoE ---------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"             # einsum (GSPMD dispatch) | scatter
    moe_group: int = 4096                # tokens per routing group (caps C)
    router_aux_loss: float = 0.01

    # SSM (Mamba2 / SSD) -------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # RG-LRU -------------------------------------------------------------------
    lru_width: int = 0

    # modality frontend stub ----------------------------------------------------
    num_prefix_embeds: int = 0           # VLM patches / audio conditioning frames

    # int8 KV-cache quantization (beyond-paper; §Perf memory-term hillclimb):
    # K/V stored as int8 with per-(token, head) f32 scales, dequantized in
    # the attention read — halves decode HBM traffic and cache footprint.
    kv_quant: bool = False

    # cost-measurement mode: fully unroll every lax.scan so XLA's cost
    # analysis (which counts loop bodies once) sees the true per-step work;
    # used only by the dry-run's small-depth extrapolation compiles.
    unroll_scans: bool = False

    # serving ------------------------------------------------------------------
    max_seq: int = 32_768
    long_context_window: int = 8_192     # ring-buffer window used for long_500k
                                         # on full-attention archs (beyond-paper)
    dtype: str = "bfloat16"

    # ---------------------------------------------------------------------------
    def __post_init__(self):
        for k in self.block_pattern:
            assert k in VALID_KINDS, k
        assert self.num_layers >= len(self.block_pattern)

    # Stage decomposition: (pattern repeated n_rep times) + tail layers.
    @property
    def n_pattern_repeats(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def tail_kinds(self) -> Tuple[str, ...]:
        r = self.num_layers % len(self.block_pattern)
        return self.block_pattern[:r]

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.layer_kind(i) for i in range(self.num_layers))

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.d_model * self.ssm_expand

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_headdim if self.ssm_headdim else 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_attention(self) -> bool:
        return any(k in (FULL_ATTN, LOCAL_ATTN) for k in self.block_pattern)

    @property
    def is_subquadratic(self) -> bool:
        """True if no layer attends to unbounded context (native long-context)."""
        return FULL_ATTN not in self.block_pattern

    # ---- parameter counting (for MODEL_FLOPS = 6 N D and memory budgeting) ----
    def param_count(self, active_only: bool = False) -> int:
        d, ff = self.d_model, self.d_ff
        n = 0
        n += self.vocab_size * d                       # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d                   # lm head
        for kind in self.layer_kinds:
            n += d                                      # pre-norm scale
            if kind in (FULL_ATTN, LOCAL_ATTN):
                n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                if self.qkv_bias:
                    n += self.q_dim + 2 * self.kv_dim
            elif kind == SSM:
                di, st, hd = self.ssm_inner, self.ssm_state, self.ssm_headdim
                nh = self.ssm_heads
                proj_in = 2 * di + 2 * st + nh          # z,x,B,C,dt
                n += d * proj_in
                n += self.conv_width * (di + 2 * st)    # depthwise conv
                n += nh * 2                             # A_log, D
                n += di * d                             # out proj
            elif kind == RGLRU:
                w = self.lru_width or d
                n += d * w * 2                          # input + gate branch
                n += self.conv_width * w                # temporal conv
                n += w * 3                              # lambda, gates
                n += w * d                              # out proj
            if ff > 0 and kind != SSM:
                n += d                                  # mlp norm
                if self.is_moe:
                    e = self.experts_per_token if active_only else self.num_experts
                    per = (2 * d * ff + ff * d) if self.glu else 2 * d * ff
                    n += e * per + d * self.num_experts  # experts + router
                else:
                    n += (2 * d * ff + ff * d) if self.glu else 2 * d * ff
        n += d                                          # final norm
        return n

    # FLOPs per token (fwd) — used by the plant model and roofline checks.
    def flops_per_token(self, context_len: int, phase: str = "decode") -> float:
        """Approximate forward FLOPs for one token at a given KV context length.

        phase='prefill' uses the average causal context (context_len/2) for
        the attention term; phase='decode' uses the full context.
        """
        d, ff = self.d_model, self.d_ff
        fl = 0.0
        for kind in self.layer_kinds:
            if kind in (FULL_ATTN, LOCAL_ATTN):
                ctx = context_len if kind == FULL_ATTN else min(context_len, self.window or context_len)
                if phase == "prefill":
                    ctx = ctx / 2.0
                fl += 2 * d * self.q_dim + 4 * d * self.kv_dim + 2 * self.q_dim * d
                fl += 4 * self.num_heads * self.head_dim * ctx   # QK^T + PV
            elif kind == SSM:
                di, st = self.ssm_inner, self.ssm_state
                fl += 2 * d * (2 * di + 2 * st + self.ssm_heads)
                fl += 2 * di * st * 2                             # state update + out
                fl += 2 * di * d
            elif kind == RGLRU:
                w = self.lru_width or d
                fl += 2 * d * w * 2 + 2 * w * d + 10 * w
            if ff > 0 and kind != SSM:
                e = self.experts_per_token if self.is_moe else 1
                per = (6 * d * ff) if self.glu else (4 * d * ff)
                fl += e * per
                if self.is_moe:
                    fl += 2 * d * self.num_experts                # router
        fl += 2 * d * self.vocab_size                             # lm head
        return fl

    # Bytes read per decoded token (weights + KV/state) — plant memory term.
    def decode_bytes_per_token(self, context_len: int, batch: int = 1) -> float:
        itemsize = 2  # bf16
        wbytes = self.param_count(active_only=True) * itemsize
        state = 0.0
        for kind in self.layer_kinds:
            if kind == FULL_ATTN:
                state += 2 * self.kv_dim * context_len * itemsize
            elif kind == LOCAL_ATTN:
                state += 2 * self.kv_dim * min(context_len, self.window) * itemsize
            elif kind == SSM:
                state += self.ssm_heads * self.ssm_headdim * self.ssm_state * itemsize
            elif kind == RGLRU:
                state += (self.lru_width or self.d_model) * itemsize
        return wbytes / max(batch, 1) + state

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Reduced variant for CPU smoke tests (2 layers, d<=512, <=4 experts).
    def smoke(self) -> "ModelConfig":
        pat = self.block_pattern
        n_layers = max(2, len(pat))
        d = min(self.d_model, 256)
        hd = 64
        nh = max(2, d // hd)
        nkv = max(1, min(self.num_kv_heads, nh))
        kw = dict(
            num_layers=n_layers, d_model=d, num_heads=nh, num_kv_heads=nkv,
            head_dim=hd, d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            window=min(self.window, 64) if self.window else 0,
            max_seq=512,
            num_prefix_embeds=min(self.num_prefix_embeds, 8),
            long_context_window=128,
        )
        if self.is_moe:
            # effectively dropless at smoke scale -> prefill/decode consistency
            kw.update(num_experts=4, experts_per_token=2, capacity_factor=8.0)
        if SSM in pat:
            kw.update(ssm_state=32, ssm_headdim=32, ssm_chunk=64)
        if RGLRU in pat:
            kw.update(lru_width=d)
        return self.replace(**kw)
