from .config import ModelConfig, FULL_ATTN, LOCAL_ATTN, SSM, RGLRU
from .transformer import (
    ShardCtx, NOSHARD, init_params, param_specs, init_cache,
    forward_train, loss_fn, prefill, decode_step, stages_of,
)

__all__ = [
    "ModelConfig", "FULL_ATTN", "LOCAL_ATTN", "SSM", "RGLRU",
    "ShardCtx", "NOSHARD", "init_params", "param_specs", "init_cache",
    "forward_train", "loss_fn", "prefill", "decode_step", "stages_of",
]
