from .config import ModelConfig, FULL_ATTN, LOCAL_ATTN, SSM, RGLRU
from .transformer import (
    ShardCtx, NOSHARD, init_params, param_specs, init_cache,
    forward_train, loss_fn, prefill, prefill_into_slot,
    prefill_chunk_into_slot, decode_step, sample_tokens,
    sample_tokens_batched, stages_of,
)

__all__ = [
    "ModelConfig", "FULL_ATTN", "LOCAL_ATTN", "SSM", "RGLRU",
    "ShardCtx", "NOSHARD", "init_params", "param_specs", "init_cache",
    "forward_train", "loss_fn", "prefill", "prefill_into_slot",
    "prefill_chunk_into_slot", "decode_step", "sample_tokens",
    "sample_tokens_batched", "stages_of",
]
