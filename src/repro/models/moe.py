"""Token-choice top-k Mixture-of-Experts FFN.

Two dispatch implementations:

* ``einsum`` — GSPMD-classic (B,S,E,C) one-hot dispatch/combine einsums.
  Robustly shardable (experts over the ``model`` mesh axis -> all-to-all is
  inserted by the partitioner) but pays O(B·S·E·C·d) dispatch FLOPs.  Used as
  the baseline; the §Perf hillclimb for the MoE pair replaces it.
* ``scatter`` — gather/scatter slot assignment: tokens are placed into
  (E*C, d) expert buffers with scatter, FFN runs as a (E,C,d)x(E,d,ff)
  batched matmul, results are gathered back.  Near-zero dispatch FLOPs.

Both produce identical outputs (tests assert allclose) including the same
capacity-drop behaviour; drops follow token order within each expert, as in
Switch/GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, activation


def init_moe(key, cfg: ModelConfig, dtype):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "router": dense_init(k1, (d, E), dtype=jnp.float32),
        "down": dense_init(k4, (E, ff, d), in_axis=-2, dtype=dtype),
    }
    if cfg.glu:
        p["gate"] = dense_init(k2, (E, d, ff), in_axis=-2, dtype=dtype)
        p["up"] = dense_init(k3, (E, d, ff), in_axis=-2, dtype=dtype)
    else:
        p["up"] = dense_init(k3, (E, d, ff), in_axis=-2, dtype=dtype)
    return p


EXPERT_LEAVES = ("down", "gate", "up")


def is_expert_leaf(cfg: ModelConfig, path, shape) -> bool:
    """Is this param-tree leaf a per-expert weight stack?

    ``path`` is a ``jax.tree_util`` key path into the stacked params pytree;
    expert leaves live under a ``"moe"`` dict with a stacked shape of
    ``(n_rep, num_experts, ...)``.  ``launch.shardings.serving_param_specs``
    uses this to shard the expert axis over the 'model' mesh axis so each
    expert's weights live on exactly one model shard."""
    keys = [getattr(k, "key", None) for k in path]
    return ("moe" in keys and keys[-1] in EXPERT_LEAVES
            and len(shape) >= 2 and shape[1] == cfg.num_experts)


def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(cfg.capacity_factor * tokens_per_group * cfg.experts_per_token
            / cfg.num_experts)
    return max(c, cfg.experts_per_token)


def _routing(cfg: ModelConfig, p, x):
    """x (B,S,d) -> (weights (B,S,k), experts (B,S,k) int32, aux_loss)."""
    logits = (x.astype(jnp.float32) @ p["router"])          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    # Switch-style load-balancing aux loss
    E = cfg.num_experts
    density = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_proxy) * E
    return w, idx, aux


def _mask_pads(cfg: ModelConfig, w, idx, valid):
    """Bucket-padding tokens must not compete for expert capacity: route pads
    to the out-of-range expert id E (whose one_hot row is zero, so they claim
    no capacity slot in ``_slots``) and zero their combine weights.  Without
    this, the slot-native bucketed prefill lets pad garbage compete for
    capacity at tight capacity factors."""
    if valid is None:
        return w, idx
    v = valid[..., None]
    return (w * v.astype(w.dtype),
            jnp.where(v, idx, cfg.num_experts))


def _dynamic_capacity(cfg: ModelConfig, valid, C: int):
    """Per-row capacity clamp from the *true* token count (traced).

    The static buffer capacity C is computed from the padded bucket length,
    which is strictly larger than the unpadded reference's — so a bucketed
    prompt at tight capacity would drop *fewer* token-choices than the same
    prompt unpadded.  Clamping ``keep`` to the capacity the unpadded length
    would produce makes bucketed routing token-for-token identical to the
    reference.  The per-count capacities are precomputed host-side through
    ``capacity`` itself (S is static), so the clamp is bit-identical to the
    reference's Python ``int()`` — no float32 floor hazards."""
    S = valid.shape[1]
    table = jnp.asarray([min(capacity(cfg, n), C) for n in range(S + 1)],
                        jnp.int32)
    n = jnp.sum(valid, axis=1)                                  # (B,)
    return table[n][:, None, None]


def _slots(cfg: ModelConfig, idx, C: int):
    """Position-in-expert for every (token, choice); >=C means dropped.

    idx: (B,S,k) int32.  Slot order = token order within the (B,) group.
    Returns (B,S,k) int32 slots.
    """
    B, S, k = idx.shape
    E = cfg.num_experts
    flat = idx.reshape(B, S * k)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)        # (B,S*k,E)
    pos = jnp.cumsum(onehot, axis=1) - 1                     # rank within expert
    slot = jnp.take_along_axis(pos, flat[..., None], axis=-1)[..., 0]
    return slot.reshape(B, S, k)


def _cs(shd, x, *dims):
    return shd.cs(x, *dims) if shd is not None else x


def _ffn(cfg: ModelConfig, p, h, shd=None):
    """h (..., E, C, d) -> (..., E, C, d); batched per-expert FFN."""
    if cfg.glu:
        g = activation(cfg, jnp.einsum("...ecd,edf->...ecf", h, p["gate"]))
        u = jnp.einsum("...ecd,edf->...ecf", h, p["up"])
        hh = g * u
    else:
        hh = activation(cfg, jnp.einsum("...ecd,edf->...ecf", h, p["up"]))
    hh = _cs(shd, hh, *(None,) * (hh.ndim - 3), "m", None, None) \
        if cfg.num_experts and hh.ndim >= 3 else hh
    return jnp.einsum("...ecf,efd->...ecd", hh, p["down"])


def moe_einsum(cfg: ModelConfig, p, x, shd=None, valid=None):
    """GSPMD dispatch-einsum MoE. x (B,S,d) -> (B,S,d), aux."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = capacity(cfg, S)
    w, idx, aux = _routing(cfg, p, x)
    w, idx = _mask_pads(cfg, w, idx, valid)
    slot = _slots(cfg, idx, C)
    keep = slot < C
    if valid is not None:
        keep &= (slot < _dynamic_capacity(cfg, valid, C)) & valid[..., None]
    slot = jnp.where(keep, slot, 0)
    # dispatch mask (B,S,E,C) accumulated one routing choice at a time so the
    # (B,S,k,E,C) intermediate never materializes (k-fold peak-memory saving)
    disp = jnp.zeros((B, S, E, C), x.dtype)
    comb = jnp.zeros((B, S, E, C), x.dtype)
    for i in range(k):
        oh = (jax.nn.one_hot(idx[..., i], E, dtype=x.dtype)
              * keep[..., i, None].astype(x.dtype))          # (B,S,E)
        sl = jax.nn.one_hot(slot[..., i], C, dtype=x.dtype)  # (B,S,C)
        term = oh[..., :, None] * sl[..., None, :]
        disp = _cs(shd, disp + term, "b", None, "m", None)
        comb = _cs(shd, comb + term * w[..., i, None, None].astype(x.dtype),
                   "b", None, "m", None)
    h = jnp.einsum("bsec,bsd->becd", disp, x)
    h = _cs(shd, h, "b", "m", None, None)
    y = _ffn(cfg, p, h, shd)                                 # (B,E,C,d)
    y = _cs(shd, y, "b", "m", None, None)
    out = jnp.einsum("bsec,becd->bsd", comb, y)
    return out, aux


def moe_scatter(cfg: ModelConfig, p, x, shd=None, valid=None):
    """Scatter/gather MoE with identical semantics to moe_einsum."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = capacity(cfg, S)
    w, idx, aux = _routing(cfg, p, x)
    w, idx = _mask_pads(cfg, w, idx, valid)
    slot = _slots(cfg, idx, C)
    keep = slot < C
    if valid is not None:
        keep &= (slot < _dynamic_capacity(cfg, valid, C)) & valid[..., None]
    dest = idx * C + jnp.where(keep, slot, 0)                # (B,S,k) in [0,E*C)
    dest = jnp.where(keep, dest, E * C)                      # drop -> overflow row
    xk = jnp.broadcast_to(x[:, :, None, :], (B, S, k, d)).reshape(B, S * k, d)
    destf = dest.reshape(B, S * k)
    buf = jnp.zeros((B, E * C + 1, d), x.dtype)
    buf = buf.at[jnp.arange(B)[:, None], destf].set(xk.astype(x.dtype))
    h = buf[:, : E * C].reshape(B, E, C, d)
    h = _cs(shd, h, "b", "m", None, None)
    y = _ffn(cfg, p, h, shd).reshape(B, E * C, d)
    y = jnp.concatenate([y, jnp.zeros((B, 1, d), y.dtype)], axis=1)
    out_k = y[jnp.arange(B)[:, None], destf].reshape(B, S, k, d)
    out = jnp.sum(out_k * w[..., None].astype(x.dtype), axis=2)
    return out, aux


def apply_moe(cfg: ModelConfig, p, x, shd=None, valid=None):
    """Routing groups (cfg.moe_group tokens) bound expert capacity C — and
    the dispatch tensor — independently of sequence length (MaxText-style).

    ``valid`` (B,S) bool marks real tokens; bucket pads (slot-native prefill)
    are excluded from expert-capacity competition (see ``_mask_pads``)."""
    B, S, d = x.shape
    fn = moe_scatter if cfg.moe_impl == "scatter" else moe_einsum
    if S > cfg.moe_group and S % cfg.moe_group == 0:
        g = S // cfg.moe_group
        xg = x.reshape(B * g, cfg.moe_group, d)
        vg = valid.reshape(B * g, cfg.moe_group) if valid is not None else None
        out, aux = fn(cfg, p, xg, shd, vg)
        return out.reshape(B, S, d), aux
    return fn(cfg, p, x, shd, valid)
