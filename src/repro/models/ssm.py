"""Mamba2 SSD (state-space duality) mixer [arXiv:2405.21060].

Training/prefill use the chunked SSD algorithm (intra-chunk quadratic form +
inter-chunk linear recurrence); decode is the O(1) recurrent update.  Shapes
follow the minimal-mamba2 formulation with a single B/C group (ngroups=1).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, rmsnorm


def init_ssm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di, st, nh = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * st
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * st + nh                      # z, xBC, dt
    return {
        "in_proj": dense_init(k1, (d, proj_out), dtype=dtype),
        "conv_w": (jax.random.normal(k2, (cfg.conv_width, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), math.log(math.expm1(0.01)), jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(k4, (di, d), dtype=dtype),
    }


def _causal_conv(x, w, b, prefix=None):
    """Depthwise causal conv. x (B,S,C), w (cw,C) -> (B,S,C).

    ``prefix`` ((B, cw-1, C)) seeds the left context (chunked prefill resumes
    from the conv window stored in the cache); default is zero padding."""
    cw = w.shape[0]
    if prefix is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(cw):
        out = out + xp[:, i: i + x.shape[1]] * w[i]
    return jax.nn.silu(out + b)


def _segsum(x):
    """x (..., T) -> (..., T, T) with out[i,j] = sum_{k=j+1..i} x[k] (j<=i),
    -inf above the diagonal."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _split(cfg: ModelConfig, zxbcdt):
    di, st, nh = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di: 2 * di + 2 * st]
    dt = zxbcdt[..., 2 * di + 2 * st:]
    return z, xBC, dt


def ssm_forward(cfg: ModelConfig, p, x, *, return_state: bool = False,
                cache=None, length=None):
    """Full-sequence SSD. x (B,S,d) -> (B,S,d) [, final caches].

    ``cache`` ({"state", "conv"}) resumes the linear recurrence and the conv
    window from an earlier segment (chunked prefill); ``length`` (traced, per
    call) marks positions >= length as bucket padding — their state update is
    the identity (dt -> 0) and the returned conv window ends at the last
    *valid* token, so pads never pollute the recurrent state.
    """
    B_, S, _ = x.shape
    di, st, nh, hd = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    cs = min(cfg.ssm_chunk, S)
    while S % cs:
        cs -= 1
    nc = S // cs

    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split(cfg, zxbcdt)
    prefix = cache["conv"] if cache is not None else None
    xBC_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], prefix)
    xs = xBC_conv[..., :di].reshape(B_, S, nh, hd).astype(jnp.float32)
    Bm = xBC_conv[..., di: di + st].astype(jnp.float32)          # (B,S,n)
    Cm = xBC_conv[..., di + st:].astype(jnp.float32)             # (B,S,n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,h)
    if length is not None:
        valid = jnp.arange(S)[None, :, None] < jnp.asarray(length, jnp.int32)
        dt = dt * valid                 # pads: dA=1, dB·x=0 -> state identity
    A = -jnp.exp(p["A_log"])                                     # (h,)

    # chunk
    xc = xs.reshape(B_, nc, cs, nh, hd)
    Bc = Bm.reshape(B_, nc, cs, st)
    Cc = Cm.reshape(B_, nc, cs, st)
    dtc = dt.reshape(B_, nc, cs, nh)
    dA = dtc * A                                                 # (B,nc,cs,h)
    dAh = jnp.moveaxis(dA, -1, 1)                                # (B,h,nc,cs)
    A_cum = jnp.cumsum(dAh, axis=-1)
    L = jnp.exp(_segsum(dAh))                                    # (B,h,nc,cs,cs)
    xdt = xc * dtc[..., None]                                    # (B,nc,cs,h,p)

    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xdt)
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)              # (B,h,nc,cs)
    states = jnp.einsum("bcsn,bhcs,bcshp->bchpn", Bc, decay_states, xdt)
    chunk_decay = jnp.exp(A_cum[..., -1])                        # (B,h,nc)

    def scan_fn(S_prev, inp):
        st_c, dec_c = inp                                        # (B,h,p,n),(B,h)
        out = S_prev
        S_new = S_prev * dec_c[..., None, None] + st_c
        return S_new, out

    states_t = jnp.moveaxis(states, 1, 0)                        # (nc,B,h,p,n)
    decay_t = jnp.moveaxis(chunk_decay, -1, 0)                   # (nc,B,h)
    S0 = cache["state"].astype(states_t.dtype) if cache is not None \
        else jnp.zeros_like(states_t[0])
    S_final, states_prev = jax.lax.scan(scan_fn, S0,
                                        (states_t, decay_t),
                                        unroll=cfg.unroll_scans)
    states_prev = jnp.moveaxis(states_prev, 0, 1)                # (B,nc,h,p,n)
    state_decay_out = jnp.exp(A_cum)                             # (B,h,nc,cs)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, states_prev, state_decay_out)

    y = (y_diag + y_off).reshape(B_, S, nh, hd)
    y = y + xs * p["D"][None, None, :, None]
    y = y.reshape(B_, S, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm"], cfg.norm_eps)
    out = y.astype(x.dtype) @ p["out_proj"]
    if not return_state:
        return out
    cw = cfg.conv_width
    if cw > 1:
        # window of the cw-1 inputs preceding the *next* position; with pads
        # (length < S) it must end at the last valid token, so slice the
        # [prefix | xBC] concat at traced index ``length``
        lead = prefix.astype(xBC.dtype) if prefix is not None else \
            jnp.zeros((B_, cw - 1, xBC.shape[-1]), xBC.dtype)
        full = jnp.concatenate([lead, xBC], axis=1)
        end = jnp.asarray(S if length is None else length, jnp.int32)
        conv_state = jax.lax.dynamic_slice_in_dim(full, end, cw - 1, axis=1)
    else:
        conv_state = jnp.zeros((B_, 0, xBC.shape[-1]), xBC.dtype)
    return out, {"state": S_final, "conv": conv_state}


def ssm_decode_step(cfg: ModelConfig, p, x, cache: Dict) -> Tuple[jax.Array, Dict]:
    """x (B,1,d) -> (B,1,d); O(1) recurrent update."""
    B_ = x.shape[0]
    di, st, nh, hd = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    zxbcdt = x[:, 0] @ p["in_proj"]
    z, xBC, dt = _split(cfg, zxbcdt)
    # conv over stored window
    window = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC[:, None]], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    xh = conv_out[..., :di].reshape(B_, nh, hd).astype(jnp.float32)
    Bm = conv_out[..., di: di + st].astype(jnp.float32)
    Cm = conv_out[..., di + st:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,h)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                          # (B,h)
    state = cache["state"] * dA[..., None, None] \
        + (dt[..., None] * xh)[..., None] * Bm[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", state, Cm) + xh * p["D"][None, :, None]
    y = y.reshape(B_, di)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm"], cfg.norm_eps)
    out = (y.astype(x.dtype) @ p["out_proj"])[:, None]
    new_cache = {"state": state, "conv": window[:, 1:].astype(cache["conv"].dtype)}
    return out, new_cache
