"""Causal flash-attention forward kernel (Pallas TPU).

Prefill attention is GreenLLM's compute hot spot (the O(n²) term that sets
the prefill energy knee).  TPU-native design:

* grid (B, Hq, n_q_blocks, n_k_blocks); the k-block dimension is innermost,
  so the online-softmax accumulators live in VMEM scratch across k steps.
* 128x128 q/k tiles (MXU-aligned), fp32 accumulation, bf16/f32 inputs.
* GQA without materializing repeated KV: the k/v BlockSpec index maps
  query head h -> kv head h // group.
* causal + sliding-window masking by block-level position arithmetic;
  fully-masked k blocks are skipped with pl.when (halves causal FLOPs).
* optional logit soft-capping (gemma2).

Validated against ref.reference_attention in interpret mode (tests/).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_q: int, block_k: int, causal: bool,
            window: int, softcap: float, num_k_blocks: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * block_q
    k_start = j * block_k

    # block-level skip: all keys after the last query position (causal), or
    # all keys before the window of the first query position
    def masked_out():
        if causal and window:
            return jnp.logical_or(k_start > q_start + block_q - 1,
                                  k_start + block_k - 1 <= q_start - window)
        if causal:
            return k_start > q_start + block_q - 1
        return jnp.asarray(False)

    @pl.when(jnp.logical_not(masked_out()))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                           # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0, ...] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q (B,Hq,Sq,hd); k,v (B,KH,Sk,hd); Hq % KH == 0. Returns (B,Hq,Sq,hd)."""
    B, Hq, Sq, hd = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    assert Hq % KH == 0
    G = Hq // KH
    scale = hd ** -0.5 if scale is None else scale
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k

    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, softcap=softcap, num_k_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
