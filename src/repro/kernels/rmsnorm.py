"""Fused RMSNorm kernel (Pallas TPU).

The per-block normalization in every layer reads and writes the full
activation; fusing mean-square, rsqrt and scale into one VMEM pass keeps it
a single HBM round trip.  Row-tiled: grid over (rows / block_rows); the full
feature dim lives in VMEM (d_model <= 8192 -> <= 64 KB f32 per row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    """x (..., d); w (d,). Row-tiled fused RMSNorm."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n = x2.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
