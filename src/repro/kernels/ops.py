"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run in interpret mode (the body executes
as jnp ops); on a real TPU set ``interpret=False`` (default decided by the
platform).  Layout conventions match the model code: (B, S, H, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash
from .decode_attention import decode_attention as _decode


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "scale", "interpret"))
def flash_attention_bshd(q, k, v, *, causal=True, window=0, softcap=0.0,
                         scale=None, interpret=None):
    """q (B,Sq,Hq,hd); k,v (B,Sk,KH,hd) -> (B,Sq,Hq,hd)."""
    interpret = _default_interpret() if interpret is None else interpret
    out = _flash(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
                 causal=causal, window=window, softcap=softcap, scale=scale,
                 interpret=interpret)
    return out.swapaxes(1, 2)


@functools.partial(jax.jit, static_argnames=("window", "scale", "interpret"))
def decode_attention_bshd(q, k, v, k_pos, q_pos, *, window=0, scale=None,
                          interpret=None):
    """q (B,1,Hq,hd); k,v (B,Sk,KH,hd); k_pos (B,Sk); q_pos (B,) ->
    (B,1,Hq,hd)."""
    interpret = _default_interpret() if interpret is None else interpret
    out = _decode(q[:, 0], k.swapaxes(1, 2), v.swapaxes(1, 2), k_pos, q_pos,
                  window=window, scale=scale, interpret=interpret)
    return out[:, None]
