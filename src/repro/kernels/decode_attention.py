"""Flash-decode kernel (Pallas TPU): one query token against a (ring-buffer)
KV cache — GreenLLM's decode-phase memory hot spot (the KV reads that make
decode memory-bound and push its energy knee below prefill's).

Design:
* grid (B, KH, n_k_blocks): per kv head, the G = Hq/KH query heads that
  share it are processed together as a (G, hd) tile; online-softmax
  accumulators persist in VMEM scratch across k blocks.
* ring-buffer support: key slot positions arrive as a precomputed int32
  array (B, Sk) (slot -> absolute position, -1 for unfilled); masking is
  `0 <= k_pos <= q_pos` plus an optional sliding window — identical
  semantics to models.kvcache.
* fp32 accumulation; bf16 cache reads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, window: int,
            block_k: int, num_k_blocks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    kpos = kpos_ref[0]                               # (bk,)
    qpos = qpos_ref[0]                               # scalar int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = jnp.logical_and(kpos >= 0, kpos <= qpos)
    if window:
        valid = jnp.logical_and(valid, kpos > qpos - window)
    s = jnp.where(valid[None, :], s, NEG_INF)        # (G, bk)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid[None, :], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0, ...] = (acc_ref[...] / safe).astype(o_ref.dtype)


def decode_attention(q, k, v, k_pos, q_pos, *, window: int = 0,
                     scale: float = None, block_k: int = 256,
                     interpret: bool = False):
    """q (B,Hq,hd); k,v (B,KH,Sk,hd); k_pos (B,Sk) int32 slot positions
    (-1 = unfilled); q_pos (B,) int32. Returns (B,Hq,hd)."""
    B, Hq, hd = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    assert Hq % KH == 0
    G = Hq // KH
    scale = hd ** -0.5 if scale is None else scale
    block_k = min(block_k, Sk)
    assert Sk % block_k == 0
    nk = Sk // block_k
    qg = q.reshape(B, KH, G, hd)

    kernel = functools.partial(_kernel, scale=scale, window=window,
                               block_k=block_k, num_k_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, KH, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),                 # q_pos
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, j: (b, j)),       # k_pos
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, qg, k, v, k_pos)
    return out.reshape(B, Hq, hd)
