"""Pure-jnp oracles for the Pallas kernels (tests assert allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def reference_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                        scale=None):
    """q (B,Hq,Sq,hd); k,v (B,KH,Sk,hd) -> (B,Hq,Sq,hd)."""
    B, Hq, Sq, hd = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    scale = hd ** -0.5 if scale is None else scale
    if Hq != KH:
        rep = Hq // KH
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1, keepdims=True), p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def reference_decode_attention(q, k, v, k_pos, q_pos, *, window=0, scale=None):
    """q (B,Hq,hd); k,v (B,KH,Sk,hd); k_pos (B,Sk); q_pos (B,)."""
    B, Hq, hd = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    scale = hd ** -0.5 if scale is None else scale
    if Hq != KH:
        rep = Hq // KH
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    valid = (k_pos >= 0) & (k_pos <= q_pos[:, None])
    if window:
        valid &= k_pos > (q_pos[:, None] - window)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(valid, -1)[:, None, None], p, 0.0)
    return jnp.einsum("bhk,bhkd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)
