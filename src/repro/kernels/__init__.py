from .ops import flash_attention_bshd, decode_attention_bshd
from .rmsnorm import rmsnorm
from .decode_attention_q8 import decode_attention_q8
from . import ref
