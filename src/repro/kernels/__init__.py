from .ops import flash_attention_bshd, decode_attention_bshd
from .rmsnorm import rmsnorm
from .decode_attention_q8 import decode_attention_q8
from .paged_decode_attention import (paged_decode_attention,
                                     paged_decode_attention_ref)
from . import ref
