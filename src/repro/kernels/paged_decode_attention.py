"""Paged flash-decode kernel (Pallas TPU): one query token per stream against
a shared paged K/V pool, addressed by a per-stream page table.

This is the kernel form of the serving engine's paged decode path
(``models.kvcache.paged_cache_kv_arrays`` + masked attention is the XLA
reference): instead of gathering every stream's page chain into a dense
(B, S, KH, hd) context in HBM, the kernel walks the chain *inside* the grid —
the page table rides in as a scalar-prefetch operand, and each (batch, head,
logical-page) grid step DMAs exactly one physical page from the pool, so the
per-token read volume is the live context, never the gather materialization.

Design:
* grid (B, KH, n_pages): per kv head, the G = Hq/KH query heads sharing it
  are processed as a (G, hd) tile; online-softmax accumulators persist in
  VMEM scratch across the page dimension (same scheme as
  ``decode_attention``).
* page indirection: ``page_table`` (B, n_pages) int32 is scalar-prefetched;
  the K/V BlockSpec index maps select block ``page_table[b, j]`` of the pool
  for logical page ``j``.  Unallocated chain tails point at the scratch page
  (id 0) and are masked by position, identical to the XLA path's semantics.
* masking: key position of (page j, offset o) is ``j*ps + o`` (pages are
  linear — no ring wrap); valid iff ``<= q_pos`` plus an optional sliding
  window.  fp32 accumulation, bf16 pool reads.

Pool layout here is (num_pages, KH, page_size, hd) — page-major with the
(page_size, hd) tile minor so one block is one well-tiled VMEM page.  The
serving layout (num_pages, page_size, KH, hd) is transposed by the wrapper
(on TPU you would store the pool kernel-native and skip it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pt_ref, qpos_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, window: int,
            page_size: int, n_pages: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (ps, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    qpos = qpos_ref[b]

    # linear page chain: position of offset o in logical page j is j*ps + o
    kpos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = kpos <= qpos
    if window:
        valid = jnp.logical_and(valid, kpos > qpos - window)
    s = jnp.where(valid[None, :], s, NEG_INF)        # (G, ps)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid[None, :], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0, ...] = (acc_ref[...] / safe).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, page_table, q_pos, *,
                           window: int = 0, scale: float = None,
                           interpret: bool = False):
    """q (B,Hq,hd); k_pool/v_pool (P, ps, KH, hd) serving pool layout;
    page_table (B, n_pages) int32 physical-page ids (ctx-bucket-sliced by the
    caller — its width bounds the walked context); q_pos (B,) int32 current
    positions.  Returns (B, Hq, hd)."""
    B, Hq, hd = q.shape
    ps, KH = k_pool.shape[1], k_pool.shape[2]
    n_pages = page_table.shape[1]
    assert Hq % KH == 0
    G = Hq // KH
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(B, KH, G, hd)
    # kernel-native page-major layout: block (1, 1, ps, hd) == one pool page
    kk = jnp.swapaxes(k_pool, 1, 2)                  # (P, KH, ps, hd)
    vv = jnp.swapaxes(v_pool, 1, 2)

    kernel = functools.partial(_kernel, scale=scale, window=window,
                               page_size=ps, n_pages=n_pages)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # page_table, q_pos
        grid=(B, KH, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, pt, qp: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda b, h, j, pt, qp: (pt[b, j], h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda b, h, j, pt, qp: (pt[b, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, j, pt, qp: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, hd), q.dtype),
        interpret=interpret,
    )(page_table, q_pos, qg, kk, vv)
    return out.reshape(B, Hq, hd)


def paged_decode_attention_ref(q, k_pool, v_pool, page_table, q_pos, *,
                               window: int = 0, scale: float = None):
    """Pure-jnp oracle: gather the page chains dense, then mask + softmax with
    the same semantics (linear positions, scratch-page tails masked)."""
    B, Hq, hd = q.shape
    ps, KH = k_pool.shape[1], k_pool.shape[2]
    n = page_table.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    k = k_pool[page_table].reshape(B, n * ps, KH, hd).astype(jnp.float32)
    v = v_pool[page_table].reshape(B, n * ps, KH, hd).astype(jnp.float32)
    if Hq != KH:
        rep = Hq // KH
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    kpos = jnp.arange(n * ps, dtype=jnp.int32)
    valid = kpos[None, :] <= q_pos[:, None]
    if window:
        valid &= kpos[None, :] > (q_pos[:, None] - window)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), k) * scale
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(valid, axis=1)[:, None, None], p, 0.0)
    return jnp.einsum("bhs,bshd->bhd", p, v).astype(q.dtype)
