"""Slot-native real-execution serving engine: fully-jitted continuous batching
driven by the same GreenLLM control plane as the simulator.

Data-plane design (the hot path):

* **Bucketed slot prefill** — prompts are right-padded to a small set of
  power-of-two buckets (bounding compile count to O(log max_len)) and run
  through ``models.prefill_into_slot``, which writes K/V (and SSM/RG-LRU
  states) directly into one row of the shared batch cache via
  ``dynamic_update_slice`` inside the jitted computation.  Admission never
  allocates a per-request cache and never splices the full batch cache on the
  host.  Prompts longer than every attention buffer (sliding-window /
  long-context ring caches) fall back to the reference ``models.prefill`` +
  host splice path.
* **Donated decode step** — one ``jax.jit(..., donate_argnums=...)`` step
  carries per-slot position vectors and an active-slot mask: each stream
  attends to *its own* context (not the batch-wide ``max(pos)``), inactive
  rows hold position, and the donated caches update in place instead of being
  copied twice per token.
* **On-device per-slot sampling** — sampling is a per-slot vectorized
  property of the jitted step: each batch row carries its own temperature /
  top-k / top-p lane plus a PRNG *base* key in device vectors
  (``sample_tokens_batched``), so heterogeneous requests (greedy code
  completion next to nucleus-sampled creative writing) share one batch with
  no static sampling arguments and **no per-token host transfer**: the
  per-slot token ids are drained once per block, sized to the next stream
  join/leave event.  Draw subkeys fold the token's sequence position into
  the row's base lane — the lane itself never advances, so a stream's i-th
  draw is a pure function of ``(lane, position)`` and seeded streams replay
  identical tokens across runs, migrations, and recompute-on-resume.
* **Paged KV cache** (``EngineConfig.paged=True``) — full-length attention
  buffers become a shared pool of fixed-size pages (``serving.pager``);
  streams hold page chains that grow at decode-block boundaries, so capacity
  is bounded by tokens in flight instead of ``max_batch x max_len`` and pool
  exhaustion preempts the youngest stream (freed pages + recompute-on-resume)
  rather than failing.  Page-table updates ride the existing block cadence —
  the no-per-token-host-sync invariant holds.
* **Chunked prefill** (``EngineConfig.chunked_prefill=True``, the default) —
  prompts longer than the largest bucket are split into bucket-sized chunks
  admitted across successive decode blocks (Sarathi-style), each chunk a
  jitted ``prefill_chunk_into_slot`` call that attends to the stream's cached
  context; sliding-window and long-context configs stay on the slot-native
  path end to end instead of falling back to the eager reference prefill.
* **Stream migration** (``export_stream`` / ``import_stream``) — a decodable
  stream is a first-class movable object: its page-chain K/V, bounded dense
  rows, recurrent (SSM/RG-LRU) row state, position and last token transfer
  into another engine's pool in O(context) data (no full-length buffer is
  ever copied), which is what makes disaggregated prefill/decode replicas
  (``serving.cluster``) a cheap placement decision instead of a data-plane
  rewrite.  Pool pressure can preempt streams in *either* phase (decoding or
  mid-chunked-prefill), youngest-first, with recompute-on-resume.

On this CPU container the engine runs reduced models; *virtual time* for
SLO/energy accounting comes from the calibrated plant model (wall-clock CPU
time of a smoke-scale model says nothing about an A100/TPU), while the token
*values* are produced by the real network.  On real hardware, set
``use_wall_clock=True`` to account with measured per-block latencies instead.

``EngineConfig(slot_native=False)`` keeps the pre-slot data plane (per-request
prefill + full-cache splice, per-step host sync, batch-wide ``max(pos)``) as a
benchmark baseline; it is deprecated for serving because mixed-position
batches attend to the wrong context there.
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CounterfactualPricer, DualLoopController,
                        MaxFreqController, Request, RequestState,
                        SamplingParams, ServingReport, SLOConfig, StateEvent,
                        TokenEvent, build_report, make_router)
from repro.core.telemetry import OccupancyMeter, TBTMeter
from repro.launch.shardings import (gather_replicated, make_serving_shard_ctx,
                                    named, serving_param_specs,
                                    shard_serving_caches)
from repro.models import (ModelConfig, NOSHARD, init_cache, init_params,
                          prefill, prefill_into_slot, prefill_chunk_into_slot,
                          decode_step, sample_tokens_batched)
from repro.models.config import FULL_ATTN, LOCAL_ATTN
from repro.models.kvcache import (attn_buffer_len, is_paged,
                                  paged_chain_extract, paged_chain_insert,
                                  paged_page_copy,
                                  cache_row_extract, cache_row_insert)
from repro.sim import PlantModel
from repro.sim.profiling import profile_decode_table
from repro.core.hardware import HardwareProfile, A100_SXM4_40G
from .pager import PageAllocator
from .prefix_cache import PrefixCache

# CPU XLA has no buffer donation; the jitted step is still correct, so keep
# the log quiet on smoke runs (donation engages on TPU/GPU).
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


# -- jitted kernels (module level: JAX's global jit cache shares compiles
# across engine instances; cfg/temp/ctx/k/max_len are static) -----------------

def _sliceable(leaf_len: int, ctx: int, max_len: int) -> bool:
    # only full-length attention buffers are position==slot and safe to
    # truncate; windowed/long-context ring buffers are already bounded
    return leaf_len == max_len and ctx < leaf_len


def _slice_caches(caches, ctx: int, max_len: int):
    out = []
    for stage in caches:
        blocks = []
        for d in stage:
            if "k" in d and _sliceable(d["k"].shape[2], ctx, max_len):
                blocks.append({kk: vv[:, :, :ctx] for kk, vv in d.items()})
            else:
                blocks.append(d)
        out.append(tuple(blocks))
    return out


def _unslice_caches(caches, sliced, ctx: int, max_len: int):
    out = []
    for stage, sstage in zip(caches, sliced):
        blocks = []
        for d, sd in zip(stage, sstage):
            if "k" in d and _sliceable(d["k"].shape[2], ctx, max_len):
                blocks.append({
                    kk: jax.lax.dynamic_update_slice(
                        d[kk], sd[kk], (0,) * d[kk].ndim)
                    for kk in d})
            else:
                blocks.append(sd)
        out.append(tuple(blocks))
    return out


def _row_subkeys(keys, positions):
    """One draw subkey per batch row: fold each token's sequence position
    into the row's PRNG *base* lane.  Lanes never advance — draw i is a pure
    function of (lane, position i) — which is exactly what makes seeded
    streams replay identical tokens across migration and recompute-on-resume
    (the lane and the position both travel with the stream)."""
    return jax.vmap(jax.random.fold_in)(
        keys, jnp.asarray(positions, jnp.int32))


def _sample_rows(sampled, logits, pos_next, keys, temps, topk, topp):
    """Shared sampling tail of the decode/prefill kernels: per-row
    temperature/top-k/top-p lanes when ``sampled`` (a host-known static:
    does any live row sample?), plain argmax otherwise — all-greedy blocks
    never pay for the sampler's sort."""
    if sampled:
        return sample_tokens_batched(logits, temps, topk, topp,
                                     _row_subkeys(keys, pos_next))
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5),
                   donate_argnums=(8,))
def _decode_block_kernel(cfg, shd, ctx, k, max_len, sampled,
                         params, tok, caches, pos, active, keys, temps,
                         topk, topp):
    """k fused decode steps (lax.scan) over caches sliced to ``ctx`` positions.

    One compile per (cfg, shd, ctx_bucket, k_block, sampled).  While every
    active position stays < ctx, the sliced cache behaves exactly like a
    max_len==ctx cache (slot == position, nothing masked away), so the block
    is equivalent to k single full-cache steps; the donated full caches are
    updated in place via a slice-in/slice-out pair amortized over the k
    steps.  The sampled token at row r lands at position ``pos[r] + 1``, so
    its subkey is ``fold_in(keys[r], pos[r] + 1)`` — no key state threads
    through the scan.

    ``shd`` (a hashable ShardCtx; NOSHARD off-mesh) is the serving mesh
    context: storage-sharded params are gathered to replicated at entry and
    every other operand stays sharded along the data axis only, so the
    sharded block is bit-identical to the single-device one.
    """
    params = gather_replicated(params, shd.mesh)
    sliced = _slice_caches(caches, ctx, max_len)

    def body(carry, _):
        tok, sl, pos = carry
        logits, sl = decode_step(params, cfg, tok[:, None], sl, pos,
                                 shd=shd, active=active)
        nxt = _sample_rows(sampled, logits, pos + 1, keys, temps, topk, topp)
        tok = jnp.where(active, nxt, tok)
        pos = pos + active.astype(jnp.int32)
        return (tok, sl, pos), tok

    (tok, sliced, pos), toks = jax.lax.scan(
        body, (tok, sliced, pos), None, length=k)
    caches = _unslice_caches(caches, sliced, ctx, max_len)
    return tok, caches, pos, toks


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3), donate_argnums=(6,))
def _paged_decode_block_kernel(cfg, shd, k, sampled, params, tok, caches, pt,
                               pos, active, keys, temps, topk, topp):
    """k fused decode steps against paged K/V pools.

    Context bucketing rides on the *shape* of ``pt`` (the page table sliced to
    the pages covering the current ctx bucket): one compile per (cfg, shd,
    n_ctx_pages, k_block, sampled).  The caller guarantees every active chain
    covers ``pos + k`` before dispatch, so the in-scan writes never leave the
    table slice; retired rows' table entries point at the scratch page.

    On a serving mesh (``shd.mesh`` set) the pool's page axis and the
    table's slot axis are sharded along 'data'; the page gather/scatter is
    cross-shard data movement, so tokens stay bit-identical to the
    single-device kernel.
    """
    params = gather_replicated(params, shd.mesh)

    def body(carry, _):
        tok, cs, pos = carry
        logits, cs = decode_step(params, cfg, tok[:, None], cs, pos,
                                 shd=shd, page_table=pt, active=active)
        nxt = _sample_rows(sampled, logits, pos + 1, keys, temps, topk, topp)
        tok = jnp.where(active, nxt, tok)
        pos = pos + active.astype(jnp.int32)
        return (tok, cs, pos), tok

    (tok, caches, pos), toks = jax.lax.scan(
        body, (tok, caches, pos), None, length=k)
    return tok, caches, pos, toks


@functools.partial(jax.jit, static_argnums=(0,))
def _decode_legacy_kernel(cfg, params, tok, caches, pos):
    return decode_step(params, cfg, tok, caches, pos)


def _slot_row(v, slot):
    """(1, ...) slice of per-slot sampling state at a traced slot index."""
    return jax.lax.dynamic_slice_in_dim(v, slot, 1, axis=0)


@functools.partial(jax.jit, static_argnums=(0, 1, 2), donate_argnums=(6,))
def _prefill_kernel(cfg, shd, sampled, params, toks, length, caches, slot,
                    pt_row, tok, pos, keys, temps, topk, topp):
    """Bucketed slot prefill + first-token sampling (one compile per
    (bucket size, shd, sampled), the former carried by the static shape of
    ``toks``).  ``pt_row`` is the stream's (1, n_pages) page-table row for
    paged caches, or None.  The first token lands at position ``length``,
    so its draw subkey is ``fold_in(keys[slot], length)``."""
    params = gather_replicated(params, shd.mesh)
    logits, caches, _ = prefill_into_slot(params, cfg, toks, length, caches,
                                          slot, shd=shd, page_table=pt_row)
    L = jnp.asarray(length, jnp.int32)
    ptok = _sample_rows(sampled, logits, L[None], _slot_row(keys, slot),
                        _slot_row(temps, slot), _slot_row(topk, slot),
                        _slot_row(topp, slot))[0]
    tok = tok.at[slot].set(ptok)
    pos = pos.at[slot].set(length)
    return tok, caches, pos


@functools.partial(jax.jit, static_argnums=(0, 1, 2), donate_argnums=(7,))
def _chunk_prefill_kernel(cfg, shd, sampled, params, toks, start, length,
                          caches, slot, pt_row, tok, pos, keys, temps, topk,
                          topp):
    """One chunk of a chunked prefill + (provisional) next-token sampling.

    Compile count is |chunk buckets| x |ctx buckets| x sampled (the ctx
    buckets via the static shape of ``pt_row`` for paged caches; dense rows
    are read at their full static buffer length).  Every chunk samples into
    ``tok[slot]`` — cheap, and only the final chunk's sample survives to
    seed decoding — and advances ``pos[slot]`` to ``start + length`` so
    occupancy tracking sees partially-prefilled streams.  The final chunk's
    draw position ``start + length`` equals the total prompt length, i.e.
    exactly ``_prefill_kernel``'s subkey for the same prompt; intermediate
    chunks' provisional draws are discarded and touch no lane state, so a
    recompute-on-resume replay (which discards even the final draw in favor
    of ``resume_tok``) cannot perturb the stream's draw sequence.
    """
    params = gather_replicated(params, shd.mesh)
    logits, caches = prefill_chunk_into_slot(params, cfg, toks, start, length,
                                             caches, slot, shd=shd,
                                             page_table=pt_row)
    end = jnp.asarray(start, jnp.int32) + jnp.asarray(length, jnp.int32)
    ptok = _sample_rows(sampled, logits, end[None], _slot_row(keys, slot),
                        _slot_row(temps, slot), _slot_row(topk, slot),
                        _slot_row(topp, slot))[0]
    tok = tok.at[slot].set(ptok)
    pos = pos.at[slot].set(start + length)
    return tok, caches, pos


@functools.partial(jax.jit, donate_argnums=(0,))
def _page_copy_kernel(caches, src, dst):
    """Copy physical page ``src`` onto ``dst`` in every paged pool leaf —
    the device half of copy-on-write (``PageAllocator.cow_page`` is the host
    half).  Only dispatched when the engine is fully paged (every cache leaf
    a page pool), so a uniform tree map is safe; donation keeps it from
    duplicating the pools."""
    out = []
    for stage in caches:
        out.append(tuple(paged_page_copy(d, src, dst) for d in stage))
    return out


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_len: int = 256
    governor: str = "greenllm"      # greenllm | defaultnv
    use_wall_clock: bool = False    # account measured latency per decode block
    slot_native: bool = True        # False -> legacy data plane (benchmarks)
    decode_block: int = 64          # max decode steps in flight per host drain
    min_bucket: int = 16            # smallest prefill padding bucket
    # paged KV cache (serving.pager): full-length attention buffers become a
    # shared page pool; capacity = tokens in flight, not max_batch * max_len
    paged: bool = False
    page_size: int = 16             # tokens per page
    num_pages: int = 0              # per-layer pool size incl. scratch page;
    #                                 0 -> dense-equivalent capacity
    # split prompts longer than the largest bucket into bucket-sized chunks
    # admitted across successive decode blocks (False -> legacy eager-prefill
    # fallback; forced True when paged)
    chunked_prefill: bool = True
    cache_dtype: str = "bfloat16"   # K/V buffer dtype (f32 for exactness tests)
    # deadline-aware admission (graceful degradation under overload): a
    # request whose absolute deadline has already passed when it reaches the
    # queue head is SHED instead of served — burning prefill+decode energy
    # on a guaranteed SLO miss only delays every request behind it
    shed_past_deadline: bool = True
    # content-addressed prefix cache (serving.prefix_cache): admission
    # matches the longest cached page-aligned prompt prefix and shares those
    # pages (refcounted, copy-on-write) instead of re-prefilling them.
    # Requires paged; only fully-paged models (dense/GQA/kv_quant full
    # attention) actually share — hybrids with ring/recurrent state always
    # miss.  Off by default: bare runs are step-for-step identical to
    # pre-cache behavior.
    prefix_cache: bool = False
    prefix_cache_pages: int = 0     # retained-page cap (0 = pool-pressure
    #                                 bounded: reclaim on allocation failure)
    # deadline-aware eviction of *admitted* decoding streams (opt-in): a
    # stream whose absolute deadline lapses mid-decode is freed via the
    # cancel machinery and reported SHED — the tokens it would still emit
    # are guaranteed-late, so the energy belongs to streams that can pass
    evict_lapsed: bool = False
    # SLO targets for stats() pass-rate reporting (parity with
    # sim.replay.Metrics); virtual-time accounting itself is unaffected
    slo: SLOConfig = dataclasses.field(default_factory=SLOConfig)
    # (data, model) serving mesh shape: the replica's data plane spans a
    # device mesh slice instead of one chip.  Per-slot state, cache rows and
    # the page pool/table shard along 'data'; params are storage-sharded and
    # gathered at kernel entry, so every mesh shape serves bit-identically
    # to mesh=None (the sharded==single-device invariant).  None: unsharded.
    mesh: Optional[tuple] = None

    def __post_init__(self):
        """Reject impossible configurations here, with a readable message,
        instead of letting them fail deep inside jitted shape logic."""
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_len < 2:
            raise ValueError(f"max_len must be >= 2, got {self.max_len}")
        if self.decode_block < 1:
            raise ValueError(
                f"decode_block must be >= 1, got {self.decode_block}")
        if self.min_bucket < 1:
            raise ValueError(
                f"min_bucket must be >= 1, got {self.min_bucket}")
        if self.min_bucket > max(self.max_len // 2, 1):
            raise ValueError(
                f"min_bucket={self.min_bucket} exceeds the prefill bucket "
                f"cap max_len//2={self.max_len // 2} (prompts are truncated "
                f"to max_len//2, so no bucket could ever be used)")
        if self.paged:
            if not self.slot_native:
                raise ValueError(
                    "paged KV requires the slot-native data plane "
                    "(slot_native=True)")
            if self.page_size < 1:
                raise ValueError(
                    f"page_size must be >= 1, got {self.page_size}")
            if self.max_len % self.page_size:
                raise ValueError(
                    f"max_len={self.max_len} must be divisible by "
                    f"page_size={self.page_size}: pages are linear "
                    "(position == logical index) and ctx buckets round to "
                    "page multiples")
            if self.num_pages and self.num_pages < 2:
                # undersized pools (< one page per slot) are legal: pool
                # pressure is handled by preemption + recompute-on-resume.
                # But page 0 is the reserved scratch page, so the pool
                # needs at least one usable page beyond it.
                raise ValueError(
                    f"num_pages={self.num_pages} leaves no usable pages: "
                    "page 0 is the reserved scratch page (need num_pages "
                    ">= 2, or 0 for dense-equivalent capacity)")
        if self.mesh is not None:
            try:
                dp, tp = (int(v) for v in self.mesh)
            except (TypeError, ValueError):
                raise ValueError(
                    f"mesh must be a (data, model) pair, got {self.mesh!r}")
            self.mesh = (dp, tp)
            if dp < 1 or tp < 1:
                raise ValueError(
                    f"mesh axes must be >= 1, got mesh=({dp},{tp})")
            if not self.slot_native:
                raise ValueError(
                    "mesh serving requires the slot-native data plane "
                    "(slot_native=True): the legacy plane is a single-"
                    "device benchmark baseline")
            if self.max_batch % dp:
                raise ValueError(
                    f"max_batch={self.max_batch} is not divisible by the "
                    f"data axis dp={dp}: per-slot state, cache rows, and "
                    "the page table shard max_batch rows along 'data' — "
                    "raise max_batch or shrink dp")
            if self.paged and self.num_pages and self.num_pages % dp:
                raise ValueError(
                    f"num_pages={self.num_pages} is not divisible by the "
                    f"data axis dp={dp}: the paged KV pool shards its page "
                    "axis along 'data' — round num_pages up to a multiple "
                    "of dp (or pass num_pages=0 for an auto-sized pool)")
        if self.prefix_cache and not self.paged:
            raise ValueError(
                "prefix_cache=True requires paged=True: cache entries are "
                "refcounted pages in the PageAllocator pool")
        if self.prefix_cache_pages < 0:
            raise ValueError(
                f"prefix_cache_pages must be >= 0, "
                f"got {self.prefix_cache_pages}")


@dataclasses.dataclass
class StreamHandoff:
    """A stream extracted from one engine for adoption by another (the
    disaggregated prefill->decode migration unit).

    ``blocks`` parallels the engine cache pytree: per stage, a tuple of
    ``("pages", extracted_chain_dict | None)`` for paged attention pools
    (only the live chain's pages — O(context) data, never a full-length
    buffer) or ``("row", row_dict)`` for bounded dense buffers (sliding-
    window rings) and recurrent SSM/RG-LRU states.  Together with ``pos``,
    ``last_token``, the sampling params and the PRNG lane this is the
    *complete* decodable state of the stream: import followed by decode is
    token-for-token identical to never having migrated — including sampled
    streams, because ``rng_lane`` (the never-advancing base key; draw i
    folds in token position i) travels with the stream and the adopter
    continues the same draw sequence.
    """
    req: Request
    pos: int
    last_token: int
    n_pages: int                    # chain length to adopt (0 = nothing paged)
    blocks: List                    # per-stage tuples of (kind, payload)
    export_time: float              # exporter's vtime at extraction
    page_size: int = 0              # 0 when the exporter is unpaged
    cfg_name: str = ""              # guard against cross-model migration
    sampling: Optional[SamplingParams] = None   # per-request sampling config
    rng_lane: Optional[object] = None  # (2,) uint32 base lane (np.ndarray)
    # the stream's partial energy ledger (core.attribution.LedgerCarry):
    # migrated requests keep their attributed joules across replicas.  A
    # no-op on adoption when both replicas share one ledger (the cluster).
    ledger_carry: Optional[object] = None
    # exporter's (data, model) mesh shape (None = unsharded): the adopter
    # rejects a mismatch the same way it rejects cfg/page_size mismatches —
    # handoff payloads are sharded pytrees, and adopting them onto a
    # different mesh would silently reshard mid-stream
    mesh_shape: Optional[tuple] = None


class _Stream:
    def __init__(self, req: Request, slot: int, last_token: int, pos: int,
                 order: int = 0):
        self.req = req
        self.slot = slot
        self.last_token = last_token
        self.pos = pos
        self.order = order          # admission sequence; preemption victims
        #                             are chosen youngest-first


class _ChunkState:
    """A stream mid-chunked-prefill: owns a slot (and page chain) but does
    not decode yet; ``tokens`` is the full context to prefill and ``start``
    the next chunk's absolute position.  ``resume_tok`` carries the
    already-sampled next token of a preempted stream being recomputed."""

    def __init__(self, req: Request, slot: int, tokens: np.ndarray,
                 resume_tok: Optional[int] = None, order: int = 0):
        self.req = req
        self.slot = slot
        self.tokens = tokens
        self.start = 0
        self.resume_tok = resume_tok
        self.order = order          # admission sequence (preemption victims
        #                             are youngest-first across phases)
        self.billed = False         # first *computed* chunk sets
        #                             prefill_start (prefix-cache hits start
        #                             at start > 0, so "start == 0" can't
        #                             identify the first chunk)


class ServingEngine:
    """Batched decode over a shared slotted KV cache (continuous batching)."""

    def __init__(self, cfg: ModelConfig, params=None, *,
                 ecfg: Optional[EngineConfig] = None,
                 hw: HardwareProfile = A100_SXM4_40G, seed: int = 0,
                 plant_cfg: ModelConfig = None, plant: PlantModel = None,
                 decode_table=None, controller=None, name: str = "engine",
                 metrics=None, tracer=None, ledger=None):
        # plant_cfg: config used for virtual-time/energy accounting (e.g. the
        # FULL model) while `cfg` (possibly reduced) produces real tokens.
        # plant / decode_table / controller: cluster injection points — a
        # multi-replica cluster shares one offline profiling pass and gives
        # each replica its role's controller (prefill-optimizer-driven or
        # dual-loop) instead of re-profiling per engine.
        # name / metrics / tracer: observability — `name` labels this
        # engine's series and spans (the cluster passes the replica name);
        # metrics is a core.MetricsRegistry, tracer a core.tracing.Tracer.
        # Both default to None = every emission site is skipped (the
        # events_on zero-overhead pattern).
        self.cfg = cfg
        self.ecfg = ecfg = ecfg if ecfg is not None else EngineConfig()
        self.params = params if params is not None else init_params(
            jax.random.PRNGKey(seed), cfg)
        self.router = make_router(ecfg.governor.lower() != "defaultnv")
        self.plant = plant if plant is not None else PlantModel(
            cfg=plant_cfg or cfg, hw=hw, n_chips=1, seed=seed)
        if controller is not None:
            self.controller = controller
        elif ecfg.governor.lower() == "greenllm":
            table = decode_table if decode_table is not None else \
                profile_decode_table(self.plant)
            self.controller = DualLoopController(hw, table)
        else:
            self.controller = MaxFreqController(hw)

        B = ecfg.max_batch
        # serving mesh (None = classic single-device plane).  Built before
        # any device allocation so params/caches/slot vectors land sharded.
        self.mesh = None
        self._shd = NOSHARD
        if ecfg.mesh is not None:
            self._validate_mesh(cfg, ecfg)
            from repro.launch.mesh import make_serving_mesh
            self.mesh = make_serving_mesh(*ecfg.mesh)
            self._shd = make_serving_shard_ctx(self.mesh)
            from jax.sharding import NamedSharding, PartitionSpec
            self._dp_rows = NamedSharding(self.mesh, PartitionSpec("data"))
            self._dp_keys = NamedSharding(self.mesh,
                                          PartitionSpec("data", None))
            specs, _ = serving_param_specs(cfg, self.mesh)
            self.params = jax.device_put(self.params,
                                         named(self.mesh, specs))
        # paged mode needs chunking (preemption resume replays arbitrary-
        # length contexts); tracked engine-side, the caller's config is
        # never mutated
        self._chunked = bool(ecfg.chunked_prefill or ecfg.paged)
        if ecfg.paged:
            ps = ecfg.page_size
            self._max_pages = -(-ecfg.max_len // ps)
            n_pages = ecfg.num_pages or (B * self._max_pages + 1)
            if self.mesh is not None:
                # auto-sized pools round up so the page axis stays divisible
                dp = ecfg.mesh[0]
                n_pages = -(-n_pages // dp) * dp
            self.pager = PageAllocator(n_pages, ps, B, self._max_pages)
            if self.mesh is not None:
                # (max_streams, max_pages) table rows shard along 'data'
                self.pager.device_sharding = self._dp_keys
            pool = (n_pages, ps)
        else:
            self.pager = None
            pool = None
        self.caches = init_cache(cfg, B, ecfg.max_len,
                                 dtype=jnp.dtype(ecfg.cache_dtype),
                                 paged_pool=pool)
        if self.mesh is not None:
            self.caches = shard_serving_caches(self.caches, self.mesh)
        # prefix sharing is only sound when *every* cache leaf is a page
        # pool: ring buffers and recurrent states carry per-position context
        # outside the pages, so a shared chain would not reconstruct the
        # stream.  Hybrid models keep the cache object (counters report the
        # misses) but never share or register.
        self._cacheable = ecfg.prefix_cache and all(
            is_paged(d) for stage in self.caches for d in stage)
        self.prefix_cache = PrefixCache(
            self.pager, ecfg.prefix_cache_pages) \
            if ecfg.prefix_cache else None
        self.active: Dict[int, _Stream] = {}
        self.prefilling: Dict[int, _ChunkState] = {}
        self.free_slots = list(range(B))
        self.pending: List[Request] = []
        self.vtime = 0.0
        self.energy_j = 0.0
        # per-phase accounting (matches sim.replay.Metrics: prefill vs decode
        # energy and token counts so real-engine and simulator runs compare)
        self.prefill_energy_j = 0.0
        self.decode_energy_j = 0.0
        self.idle_energy_j = 0.0    # billed when waiting on future arrivals
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self._occupancy = OccupancyMeter()   # pool-pressure telemetry
        self._order = 0
        self._tbt: Dict[int, List[float]] = {}
        self._completed = 0
        self._preempted = 0
        self._cancelled = 0
        self._failed = 0     # given up via fail() (watchdog / crash cleanup)
        self._shed = 0       # dropped by deadline-aware admission
        self._imported = 0   # adopted handoffs (report().migrated);
        #                      exports are counted by the cluster's Replica
        self.requests: List[Request] = []  # everything this engine has seen
        self._events: List = []     # buffered stream events (drain_events)
        # False -> skip event buffering entirely (serving.api.Server clears
        # this unless an on_event callback is installed)
        self.events_on = True
        # observability: always-on host-sync audit counter (one int += per
        # block — the zero-overhead regression test compares it across
        # sinks-on/sinks-off runs), plus optional metric/trace sinks
        self.name = name
        self._host_drains = 0
        self.metrics = None
        self.tracer = None
        self.ledger = None          # core.attribution.EnergyLedger (opt-in)
        self._cf = None             # counterfactual pricer (with ledger)
        self._m = None              # bound metric children (when metrics)
        self._obs_tbt = None        # engine-level TBT window for p95/p99
        if metrics is not None or tracer is not None or ledger is not None:
            self.install_observability(metrics, tracer, ledger)

        # device-resident decode state (slot-native path)
        self._tok = self._row_pin(jnp.zeros((B,), jnp.int32))
        self._pos = self._row_pin(jnp.zeros((B,), jnp.int32))
        self._active_host = np.zeros(B, bool)
        self._active = self._row_pin(jnp.asarray(self._active_host))
        # per-slot sampling lanes: temperature / top-k / top-p vectors plus
        # each row's PRNG *base* key.  Draw subkeys fold the token position
        # into the base lane (see _row_subkeys), so lanes never advance —
        # a stream's i-th draw is a pure function of (lane, position), which
        # is what makes migration and recompute-on-resume replay identical
        # draws.  Rows are written at slot assignment (admission / chunked
        # start / import), read only inside the jitted kernels.
        self._temps = self._row_pin(jnp.zeros((B,), jnp.float32))
        self._topk = self._row_pin(jnp.zeros((B,), jnp.int32))
        self._topp = self._row_pin(jnp.ones((B,), jnp.float32))
        self._keys = self._row_pin(jnp.zeros((B, 2), jnp.uint32))
        self._sampled_host = np.zeros(B, bool)  # host mirror of temps > 0
        self._base_key = jax.random.PRNGKey(seed + 1)  # unseeded-lane source

        # prefill buckets: powers of two, capped by the smallest attention
        # buffer (window / long-context ring) — longer prompts take the
        # reference path — and by the prompt truncation length.
        attn_kinds = [k for k in set(cfg.layer_kinds)
                      if k in (FULL_ATTN, LOCAL_ATTN)]
        slot_cap = min([attn_buffer_len(cfg, k, ecfg.max_len, False)
                        for k in attn_kinds] or [ecfg.max_len])
        if slot_cap < ecfg.min_bucket:
            raise ValueError(
                f"min_bucket={ecfg.min_bucket} exceeds the smallest "
                f"attention buffer ({slot_cap} positions — sliding-window / "
                f"long-context ring) of model '{cfg.name}': no prefill "
                "bucket would fit a slot write; lower EngineConfig.min_bucket"
            )
        cap = min(slot_cap, max(ecfg.max_len // 2, 1))
        self.buckets: List[int] = []
        b = ecfg.min_bucket
        while b <= cap:
            self.buckets.append(b)
            b *= 2
        if not self.buckets or self.buckets[-1] != cap:
            # close the (largest_pow2, cap] gap: prompts are truncated to at
            # most cap, so with a final cap-sized bucket nothing falls back
            # to the legacy path for length alone
            self.buckets.append(cap)

        # context buckets for decode: attention cost is O(cache buffer), so
        # the decode kernel runs over the cache sliced to the smallest bucket
        # covering every active position in the block, then splices back.
        # Paged mode slices the *page table* instead, so buckets are rounded
        # up to page multiples (compile count stays |ctx_buckets|).
        self.ctx_buckets: List[int] = []
        b = max(ecfg.min_bucket, 32)
        while b < ecfg.max_len:
            self.ctx_buckets.append(b)
            b *= 2
        self.ctx_buckets.append(ecfg.max_len)
        if ecfg.paged:
            ps = ecfg.page_size
            self.ctx_buckets = sorted({-(-c // ps) * ps
                                       for c in self.ctx_buckets})
        # chunked prefill: chunk length = the largest admission bucket, so
        # every chunk reuses the existing bucket set (no extra compiles)
        self.chunk_len = self.buckets[-1]
        # fixed block sizes (steps fused into one jitted lax.scan) bound the
        # (ctx_bucket, k) compile count to |ctx_buckets| * |K_BLOCKS|
        self._k_blocks = tuple(sorted({1, 4, 16, ecfg.decode_block},
                                      reverse=True))
        # (ctx, kb) kernels this engine has already dispatched: wall-clock
        # accounting excludes a kernel's first block (XLA compile time would
        # otherwise be billed as decode latency and wreck the controller)
        self._warmed: set = set()

    # -- serving mesh ----------------------------------------------------------
    @staticmethod
    def _validate_mesh(cfg: ModelConfig, ecfg: "EngineConfig") -> None:
        """Model-dependent mesh divisibility, rejected with an actionable
        message instead of an opaque XLA sharding failure deep inside the
        first jitted kernel.  (Model-independent checks — max_batch/num_pages
        vs dp — live in ``EngineConfig.__post_init__``.)"""
        dp, tp = ecfg.mesh
        if tp > 1 and cfg.num_heads % tp:
            raise ValueError(
                f"model '{cfg.name}' has num_heads={cfg.num_heads}, not "
                f"divisible by the model axis tp={tp}: attention heads "
                "partition over 'model' — pick tp from the divisors of "
                "num_heads (or tp=1)")
        if tp > 1 and cfg.is_moe and cfg.num_experts % tp:
            raise ValueError(
                f"MoE model '{cfg.name}' has num_experts={cfg.num_experts}, "
                f"not divisible by the model axis tp={tp}: expert weights "
                "place each expert on exactly one model shard — pick tp "
                "from the divisors of num_experts (or tp=1)")

    def _row_pin(self, x):
        """Pin a per-slot device vector (leading dim max_batch) to its
        data-axis sharding.  Functional updates (``.at[slot].set``) and
        host re-uploads can silently drop to single-device placement; the
        re-put is a device-to-device no-op when the sharding already
        matches, and keeping operand shardings stable is what holds the
        kernel compile count at its single-device budget.  Identity off
        mesh."""
        if self.mesh is None:
            return x
        return jax.device_put(
            x, self._dp_keys if x.ndim >= 2 else self._dp_rows)

    def _pin_caches(self, caches):
        """Re-pin a cache pytree after an eager host-side rebuild (legacy
        splice, handoff import).  Device-to-device no-op when layouts already
        match; identity off mesh."""
        if self.mesh is None:
            return caches
        from repro.launch.shardings import shard_serving_caches
        return shard_serving_caches(caches, self.mesh)

    def _sync_active(self) -> None:
        """Re-upload the host active mask (one small transfer per stream
        join/leave, the pre-mesh cadence; sharded along 'data' on a mesh)."""
        self._active = self._row_pin(jnp.asarray(self._active_host))

    # -- observability ---------------------------------------------------------
    def install_observability(self, metrics=None, tracer=None,
                              ledger=None) -> None:
        """Install metric / trace / attribution sinks (``Server(metrics=...,
        tracer=..., ledger=...)`` and the cluster route through here).  Any
        may be None; with all None every emission site below is a skipped
        ``is not None`` check — the PR 5 ``events_on`` zero-overhead
        pattern.  Emission rides the existing host-sync points only:
        publishing reads host floats the engine already computed, never a
        device value.  ``ledger`` (a ``core.attribution.EnergyLedger``,
        shareable across replicas) mirrors every billed joule — and prices
        the same intervals at max frequency through a noiseless plant
        clone, so the live plant's RNG (and hence the run) is untouched."""
        self.metrics = metrics
        self.tracer = tracer
        if ledger is not None:
            self.ledger = ledger
            ledger.register(self.name)
            self._cf = CounterfactualPricer(self.plant)
        if tracer is not None:
            self.controller.on_decision = tracer.bind(self.name)
        if metrics is not None:
            self._init_metrics(metrics)

    def _init_metrics(self, reg) -> None:
        """Bind this replica's metric children once (hot paths touch bound
        children — a float add — not the label-resolution path).  Metric
        names are a stable API; see README "Observability"."""
        r = self.name
        ev = reg.counter("greenllm_requests_total",
                         "request lifecycle events", ("replica", "event"))
        slo = reg.counter("greenllm_slo_total",
                          "per-request SLO verdicts at finish",
                          ("replica", "kind", "outcome"))
        self._m = {
            "ev": {k: ev.labels(replica=r, event=k) for k in
                   ("submitted", "completed", "cancelled", "failed", "shed",
                    "preempted", "imported", "exported")},
            "slo": {(k, o): slo.labels(replica=r, kind=k, outcome=o)
                    for k in ("ttft", "tbt") for o in ("pass", "miss")},
            "tok_pf": reg.counter("greenllm_tokens_total",
                                  "tokens processed by phase",
                                  ("replica", "phase"))
                         .labels(replica=r, phase="prefill"),
            "tok_dec": reg.counter("greenllm_tokens_total", "",
                                   ("replica", "phase"))
                          .labels(replica=r, phase="decode"),
            "e_pf": reg.counter("greenllm_energy_joules_total",
                                "energy by phase (virtual-clock accounting)",
                                ("replica", "phase"))
                       .labels(replica=r, phase="prefill"),
            "e_dec": reg.counter("greenllm_energy_joules_total", "",
                                 ("replica", "phase"))
                        .labels(replica=r, phase="decode"),
            "e_idle": reg.counter("greenllm_energy_joules_total", "",
                                  ("replica", "phase"))
                         .labels(replica=r, phase="idle"),
            "e_saved": reg.counter(
                "greenllm_energy_saved_joules_total",
                "counterfactual joules saved vs max frequency (estimate)",
                ("replica",)).labels(replica=r),
            "freq": reg.gauge("greenllm_frequency_mhz",
                              "controller SM clock set point", ("replica",))
                       .labels(replica=r),
            "occ": reg.gauge("greenllm_page_occupancy",
                             "KV page-pool occupancy [0,1]", ("replica",))
                      .labels(replica=r),
            "frag": reg.gauge("greenllm_page_fragmentation",
                              "last-page slack fraction", ("replica",))
                       .labels(replica=r),
            "q_pending": reg.gauge("greenllm_queue_depth",
                                   "streams by lifecycle stage",
                                   ("replica", "queue"))
                            .labels(replica=r, queue="pending"),
            "q_prefill": reg.gauge("greenllm_queue_depth", "",
                                   ("replica", "queue"))
                            .labels(replica=r, queue="prefilling"),
            "q_active": reg.gauge("greenllm_queue_depth", "",
                                  ("replica", "queue"))
                           .labels(replica=r, queue="active"),
            "ttft": reg.histogram("greenllm_ttft_seconds",
                                  "time to first token", ("replica",),
                                  buckets=(0.05, 0.1, 0.2, 0.4, 0.8, 1.6,
                                           3.2, 6.4))
                       .labels(replica=r),
            "tbt": reg.histogram("greenllm_tbt_seconds",
                                 "time between tokens", ("replica",),
                                 buckets=(0.005, 0.01, 0.02, 0.04, 0.08,
                                          0.1, 0.15, 0.25, 0.5))
                      .labels(replica=r),
            "p95": reg.gauge("greenllm_tbt_p95_seconds",
                             "sliding-window p95 TBT", ("replica",))
                      .labels(replica=r),
            "p99": reg.gauge("greenllm_tbt_p99_seconds",
                             "sliding-window p99 TBT", ("replica",))
                      .labels(replica=r),
        }
        if self.tracer is not None:
            # ring-buffer overflow in the tracer is otherwise silent
            # truncation; surface the drop counts where dashboards look
            self._m["drop_spans"] = reg.gauge(
                "greenllm_tracer_dropped_spans",
                "trace spans lost to ring-buffer overflow").labels()
            self._m["drop_decisions"] = reg.gauge(
                "greenllm_tracer_dropped_decisions",
                "DVFS decisions lost to ring-buffer overflow").labels()
        if self.ecfg.prefix_cache:
            # registered only when caching is on: a bare engine's metric
            # families are byte-identical to pre-cache exposition
            self._m["pc_hits"] = reg.counter(
                "greenllm_prefix_cache_hits_total",
                "admissions that matched >= 1 cached prompt page",
                ("replica",)).labels(replica=r)
            self._m["pc_misses"] = reg.counter(
                "greenllm_prefix_cache_misses_total",
                "admissions with no cached prefix", ("replica",)) \
                .labels(replica=r)
            self._m["pc_evictions"] = reg.counter(
                "greenllm_prefix_cache_evictions_total",
                "cache entries reclaimed under pool pressure",
                ("replica",)).labels(replica=r)
            self._m["pc_shared"] = reg.gauge(
                "greenllm_prefix_cache_shared_pages",
                "cached pages currently shared with live streams",
                ("replica",)).labels(replica=r)
        # published-so-far totals: counters publish deltas at block cadence
        self._pub = {"e_pf": 0.0, "e_dec": 0.0, "e_idle": 0.0,
                     "e_saved": 0.0, "tok_pf": 0, "tok_dec": 0,
                     "pc_hits": 0, "pc_misses": 0, "pc_evictions": 0}
        self._obs_tbt = TBTMeter(horizon=1.0)

    def _publish_metrics(self) -> None:
        """Flush gauges + counter deltas and stamp a timeline snapshot at
        the current virtual time.  Called only from existing host-side
        points (end of a decode block, after prefill/idle accounting) —
        this is bookkeeping over already-host-resident floats."""
        m = self._m
        if m is None:
            return
        pub = self._pub
        for key, cur in (("e_pf", self.prefill_energy_j),
                         ("e_dec", self.decode_energy_j),
                         ("e_idle", self.idle_energy_j),
                         ("tok_pf", self.prefill_tokens),
                         ("tok_dec", self.decode_tokens)):
            d = cur - pub[key]
            if d > 0:
                m[key].inc(d)
                pub[key] = cur
        if self.ledger is not None:
            cur = self.ledger.replica_saved_j(self.name)
            d = cur - pub["e_saved"]
            if d > 0:                   # counters are monotone; savings can
                m["e_saved"].inc(d)     # dip (noise near f_max) — hold then
                pub["e_saved"] = cur
        if self.tracer is not None and "drop_spans" in m:
            m["drop_spans"].set(self.tracer.dropped_spans)
            m["drop_decisions"].set(self.tracer.dropped_decisions)
        m["freq"].set(self.controller.freq)
        m["q_pending"].set(len(self.pending))
        m["q_prefill"].set(len(self.prefilling))
        m["q_active"].set(len(self.active))
        if self.pager is not None:
            occ = self.pager.occupancy()
            m["occ"].set(occ["occupancy"])
            m["frag"].set(occ["fragmentation"])
        if self.prefix_cache is not None and "pc_hits" in m:
            pc = self.prefix_cache
            for key, cur in (("pc_hits", pc.hits),
                             ("pc_misses", pc.misses),
                             ("pc_evictions", pc.evictions)):
                d = cur - pub[key]
                if d > 0:
                    m[key].inc(d)
                    pub[key] = cur
            m["pc_shared"].set(pc.shared_pages())
        if self._obs_tbt is not None and len(self._obs_tbt):
            p95 = self._obs_tbt.p95(self.vtime)
            if p95 > 0.0:               # nan-safe: hold last on empty window
                m["p95"].set(p95)
                m["p99"].set(self._obs_tbt.p99(self.vtime))
        self.metrics.record_snapshot(self.vtime)

    def _obs_finish(self, req: Request) -> None:
        """Score a FINISHED request's SLO verdicts into the counters (the
        same targets ``core.report.slo_pass_metrics`` scores post-hoc)."""
        m = self._m
        if m is None:
            return
        m["ev"]["completed"].inc()
        slo = self.ecfg.slo
        if req.first_token >= 0:
            ttft = req.first_token - req.arrival
            ok = ttft <= slo.ttft_target(req.cls or "S")
            m["slo"][("ttft", "pass" if ok else "miss")].inc()
        recs = self._tbt.get(req.rid)
        if recs:
            ok = float(np.percentile(recs, 95)) <= slo.tbt_target
            m["slo"][("tbt", "pass" if ok else "miss")].inc()

    def evict(self, rid: int) -> bool:
        """Backend protocol: drop a *terminal* request's bookkeeping (its
        report row and TBT records) so a long-lived server doesn't grow
        with total traffic served.  Counters and already-published metrics
        are unaffected; ``report()`` afterwards no longer includes the
        request.  Returns False for unknown or non-terminal requests."""
        for i, req in enumerate(self.requests):
            if req.rid == rid:
                if not req.state.terminal:
                    return False
                self.requests.pop(i)
                self._tbt.pop(rid, None)
                return True
        # already gone from the report rows; still drop stray TBT records
        return self._tbt.pop(rid, None) is not None

    # -- request intake --------------------------------------------------------
    def submit(self, req: Request, prompt_tokens: Optional[np.ndarray] = None):
        if not self.ecfg.slot_native and self._resolve_sampling(req)[0] > 0.0:
            # the legacy data plane decodes host-side argmax only; silently
            # dropping a request's sampling params would be worse than the
            # old engine-global temperature mismatch error
            raise ValueError(
                "per-request sampling (temperature > 0) requires the "
                "slot-native data plane; the legacy slot_native=False "
                "baseline decodes greedily")
        if not req.cls:      # a cluster dispatcher may have classified already
            req.cls = self.router.class_names[
                self.router.classify(req.prompt_len)]
        if prompt_tokens is None:
            rng = np.random.default_rng(req.rid)
            prompt_tokens = rng.integers(
                0, self.cfg.vocab_size, size=max(req.prompt_len, 1))
        req.prompt = np.asarray(prompt_tokens, np.int32)[-self.ecfg.max_len // 2:]
        req.state = RequestState.QUEUED
        self.pending.append(req)
        self.requests.append(req)
        if self._m is not None:
            self._m["ev"]["submitted"].inc()
        if self.tracer is not None:
            self.tracer.instant("submit", req.rid, self.vtime, self.name,
                                prompt_len=req.prompt_len, cls=req.cls)

    # -- per-slot sampling lanes ------------------------------------------------
    def _emit(self, ev) -> None:
        """Buffer a stream event for ``drain_events`` consumers — skipped
        entirely when nobody listens (``events_on`` False)."""
        if self.events_on:
            self._events.append(ev)

    def _resolve_sampling(self, req: Request):
        """(temperature, top_k, top_p) for a request.  Sampling is purely
        per-request: ``temperature=None`` means greedy argmax, same as 0
        (the old ``EngineConfig.greedy``/``temperature`` engine-wide
        defaults are gone)."""
        sp = req.sampling
        if sp is None or sp.temperature is None:
            return 0.0, (int(sp.top_k) if sp else 0), \
                (float(sp.top_p) if sp else 1.0)
        return float(sp.temperature), int(sp.top_k), float(sp.top_p)

    def _lane_for(self, req: Request) -> np.ndarray:
        """The request's PRNG base lane, created on *first* admission
        (seeded requests: ``PRNGKey(seed)``; unseeded: the engine key folded
        with the rid) and pinned on the request so preemption/recompute and
        migration reuse the same draw stream instead of resampling."""
        if req.rng_lane is None:
            sp = req.sampling
            if sp is not None and sp.seed is not None:
                lane = jax.random.PRNGKey(sp.seed)
            else:
                lane = jax.random.fold_in(self._base_key, req.rid)
            req.rng_lane = np.asarray(lane, np.uint32)
        return req.rng_lane

    def _set_slot_sampling(self, slot: int, req: Request):
        """Write a stream's sampling lane into row ``slot`` of the device
        vectors (admission-time host work, amortized like the prompt copy —
        the decode loop itself never touches these from the host).  Returns
        the resolved (temperature, top_k, top_p) for callers that also
        sample host-side."""
        temp, top_k, top_p = self._resolve_sampling(req)
        self._temps = self._row_pin(self._temps.at[slot].set(temp))
        self._topk = self._row_pin(self._topk.at[slot].set(top_k))
        self._topp = self._row_pin(self._topp.at[slot].set(top_p))
        self._keys = self._row_pin(self._keys.at[slot].set(
            jnp.asarray(self._lane_for(req), jnp.uint32)))
        self._sampled_host[slot] = temp > 0.0
        return temp, top_k, top_p

    def _account_prefill_tokens(self, n_tokens: int, first: bool,
                                req: Request):
        """Bill ``n_tokens`` of prefill work (one-shot prompt or one chunk) to
        the prefill phase.  Chunk billing approximates attention-to-past as
        part of the per-chunk latency fit (Sarathi-style accounting)."""
        t_pf = self.plant.prefill_latency(n_tokens, self.controller.freq)
        p_pf = self.plant.prefill_power(n_tokens, self.controller.freq, t_pf)
        self.energy_j += t_pf * p_pf
        self.prefill_energy_j += t_pf * p_pf
        self.prefill_tokens += n_tokens
        self.vtime += t_pf
        if self.ledger is not None:
            # the prefilling stream is this interval's only resident; the
            # mirror sees the identical float the counters above added
            e = t_pf * p_pf
            self.ledger.record_prefill(
                self.name, req.rid, e, tokens=n_tokens,
                saved_j=self._cf.prefill_j(n_tokens) - e)
        if first:
            req.prefill_start = self.vtime - t_pf

    def _account_prefill(self, req: Request):
        self._account_prefill_tokens(req.prompt_len, True, req)
        req.first_token = self.vtime

    def _start_stream(self, req: Request, slot: int, tok: int, pos: int,
                      resumed: bool = False):
        self._order += 1
        st = _Stream(req, slot, tok, pos, self._order)
        if not resumed:
            req.tokens.append(tok)
            req.tokens_emitted = 1
            self._emit(TokenEvent(req.rid, self.vtime, (tok,), 1))
            if self._m is not None and req.first_token >= 0:
                self._m["ttft"].observe(
                    max(req.first_token - req.arrival, 0.0))
        req.state = RequestState.DECODING
        self._emit(StateEvent(req.rid, self.vtime, RequestState.DECODING))
        self.active[slot] = st
        self._active_host[slot] = True
        self._sync_active()

    def _pt_rows(self, slot: int, upto: int):
        """(1, n_ctx) page-table row covering positions < the smallest ctx
        bucket >= upto (static widths bound compile count)."""
        ctx = next((c for c in self.ctx_buckets if c >= upto),
                   self.ctx_buckets[-1])
        n_ctx = min(-(-ctx // self.ecfg.page_size), self._max_pages)
        return self.pager.table_device()[slot:slot + 1, :n_ctx]

    def _admit_slot(self, req: Request, slot: int):
        prompt = req.prompt
        L = len(prompt)
        bucket = next(b for b in self.buckets if b >= L)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :L] = prompt
        pt_row = None
        if self.pager is not None:
            ok = self.pager.ensure(slot, L)      # gated by _admit
            assert ok, "admission gate let an unallocatable prompt through"
            pt_row = self._pt_rows(slot, bucket)
        self._set_slot_sampling(slot, req)
        self._tok, self.caches, self._pos = _prefill_kernel(
            self.cfg, self._shd, bool(self._sampled_host[slot]),
            self.params, jnp.asarray(padded), jnp.asarray(L, jnp.int32),
            self.caches, jnp.asarray(slot, jnp.int32), pt_row,
            self._tok, self._pos, self._keys, self._temps, self._topk,
            self._topp)
        t0 = self.vtime
        self._account_prefill(req)
        if self.tracer is not None:
            self.tracer.span("prefill", req.rid, t0, self.vtime, self.name,
                             tokens=L, bucket=bucket)
        self._register_prefix(req, slot, L)
        self._publish_metrics()
        # one tiny host read per admission (the first sampled token id)
        self._start_stream(req, slot, int(self._tok[slot]), L)

    def _admit_legacy(self, req: Request, slot: int):
        """Reference path: per-request prefill + host-side batch-cache splice.

        Used for prompts that exceed an attention ring buffer (bucketed slot
        writes need S_pad <= buf_len) and by ``slot_native=False``.
        """
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        caches = init_cache(self.cfg, 1, self.ecfg.max_len,
                            dtype=jnp.dtype(self.ecfg.cache_dtype))
        logits, caches, pos = prefill(self.params, self.cfg, toks, caches)
        self.caches = self._pin_caches(jax.tree.map(
            lambda full, one: full.at[:, slot:slot + 1].set(one)
            if full.ndim >= 2 else full, self.caches, caches))
        temp, top_k, top_p = self._set_slot_sampling(slot, req)
        sub = jax.random.fold_in(
            jnp.asarray(self._lane_for(req), jnp.uint32), len(req.prompt))
        tok = int(sample_tokens_batched(
            logits, jnp.asarray([temp], jnp.float32),
            jnp.asarray([top_k], jnp.int32),
            jnp.asarray([top_p], jnp.float32), sub[None])[0])
        self._tok = self._row_pin(self._tok.at[slot].set(tok))
        self._pos = self._row_pin(self._pos.at[slot].set(len(req.prompt)))
        t0 = self.vtime
        self._account_prefill(req)
        if self.tracer is not None:
            self.tracer.span("prefill", req.rid, t0, self.vtime, self.name,
                             tokens=len(req.prompt), legacy=True)
        self._publish_metrics()
        self._start_stream(req, slot, tok, len(req.prompt))

    def _admit(self):
        while self.pending and self.free_slots:
            req = self.pending[0]
            if max(req.arrival, req.not_before) > self.vtime + 1e-12:
                break        # FIFO head not arrived yet (online traffic /
                #              crash-recovery gate); the driver jumps the
                #              clock when fully idle
            if self.ecfg.shed_past_deadline and req.deadline >= 0 \
                    and self.vtime > req.deadline + 1e-12:
                # deadline already blown before any work started: shed
                # instead of burning prefill+decode on a guaranteed miss
                # (load shedding under overload — the queue behind the head
                # is exactly what the energy would be stolen from)
                self.pending.pop(0)
                self._mark_shed(req)
                continue
            resume = bool(req.tokens)        # preempted stream: recompute
            ctx_toks = req.prompt if not resume else np.concatenate(
                [req.prompt, np.asarray(req.tokens[:-1], np.int32)])
            need = min(len(ctx_toks), self.chunk_len)
            if self.pager is not None and not self.pager.can_admit(need):
                # cached prefixes are strictly less valuable than admitting
                # live work: evict before stalling the FIFO head
                if not (self._reclaim_cached()
                        and self.pager.can_admit(need)):
                    break                    # FIFO head-of-line: wait for pages
            self.pending.pop(0)
            slot = self.free_slots.pop(0)
            # longest-cached-prefix match (after the admission gates: a
            # lookup that can't admit must not skew hit/miss counters).
            # Resumed streams match too — their prompt pages are often
            # still cached, so recompute-on-resume skips them as well.
            hit_pages: List[int] = []
            hit_tok = 0
            if self._cacheable:
                hit_pages, hit_tok = self.prefix_cache.lookup(ctx_toks)
            if self.tracer is not None:
                self.tracer.span("queue", req.rid,
                                 max(req.arrival, req.not_before),
                                 self.vtime, self.name, slot=slot,
                                 resume=resume)
            if not self.ecfg.slot_native:
                self._admit_legacy(req, slot)
            elif hit_tok or resume or len(ctx_toks) > self.buckets[-1]:
                if self._chunked:
                    self._start_chunked(req, slot, ctx_toks, resume,
                                        hit_pages, hit_tok)
                else:
                    self._admit_legacy(req, slot)
            else:
                self._admit_slot(req, slot)

    def _start_chunked(self, req: Request, slot: int, ctx_toks: np.ndarray,
                       resume: bool, hit_pages: Optional[List[int]] = None,
                       hit_tok: int = 0):
        """Admit via chunked prefill: the stream owns ``slot`` now but joins
        the decode batch only after its last chunk (``_advance_chunks``).

        A prefix-cache hit (``hit_tok`` > 0) seeds the slot's chain with the
        shared pages and starts chunking at ``hit_tok`` instead of 0 — the
        matched tokens' K/V is the cached bits, never recomputed.  When the
        match isn't page-aligned (a fully-covered prompt, capped so one real
        token remains for the first-token logits) the partially-reused last
        page is copied-on-write first: the chunk at ``hit_tok`` rewrites that
        page's final position, and shared pages are immutable."""
        if hit_tok:
            hit_tok = self._share_prefix(slot, hit_pages, hit_tok)
        self._order += 1
        self._set_slot_sampling(slot, req)
        cs = _ChunkState(
            req, slot, np.asarray(ctx_toks, np.int32),
            resume_tok=req.tokens[-1] if resume else None, order=self._order)
        cs.start = hit_tok
        self.prefilling[slot] = cs
        req.state = RequestState.PREFILLING
        self._emit(StateEvent(req.rid, self.vtime, RequestState.PREFILLING))

    def _share_prefix(self, slot: int, pages: List[int], hit_tok: int) -> int:
        """Adopt cached pages into ``slot``'s chain (refcount bump, no data
        movement), CoW the last page if the hit ends mid-page, and seed the
        device position so the held-position write of the still-inactive row
        lands at ``hit_tok`` (inside the private/unallocated region, never a
        shared page).  Returns the effective hit length — 0 when the CoW
        cannot get a page even after reclaiming, in which case the share is
        rolled back and admission proceeds as a miss."""
        ps = self.ecfg.page_size
        self.pager.share_chain(slot, pages)
        if hit_tok % ps:
            # the hit ends inside pages[-1]: CoW before the chunk at
            # hit_tok rewrites its final position
            old = pages[-1]
            new = self.pager.cow_page(slot, len(pages) - 1)
            if new is None and self._reclaim_cached():
                new = self.pager.cow_page(slot, len(pages) - 1)
            if new is None:
                self.pager.free_chain(slot)     # roll back: admit as a miss
                return 0
            if new != old:
                self.caches = _page_copy_kernel(
                    self.caches, jnp.asarray(old, jnp.int32),
                    jnp.asarray(new, jnp.int32))
        self._pos = self._row_pin(self._pos.at[slot].set(hit_tok))
        if self.tracer is not None:
            self.tracer.instant("prefix_hit", -1, self.vtime, self.name,
                                pages=len(pages), tokens=hit_tok)
        return hit_tok

    def _reclaim_cached(self) -> bool:
        """Evict up to a chunk's worth of LRU cache-only pages back to the
        pool; False when caching is off or nothing is evictable (the caller
        falls through to preemption / head-of-line wait)."""
        if self.prefix_cache is None:
            return False
        return self.prefix_cache.reclaim(
            -(-self.chunk_len // self.ecfg.page_size)) > 0

    def _register_prefix(self, req: Request, slot: int, upto: int) -> None:
        """Publish the fully-written prompt pages of ``slot``'s chain into
        the cache (dedup by digest: already-known pages are LRU-touched,
        not re-retained)."""
        if not self._cacheable or req.prompt is None:
            return
        chain = self.pager.chains.get(slot)
        if chain:
            self.prefix_cache.register(req.prompt, chain,
                                       min(upto, len(req.prompt)))

    def _advance_chunks(self) -> bool:
        """Process one chunk for every mid-prefill stream (called once per
        decode block: chunked admission interleaves with decoding instead of
        stalling it for a full long prompt).  Returns True if any advanced."""
        progressed = False
        finished: List[int] = []
        for slot, cs in list(self.prefilling.items()):
            if slot not in self.prefilling:
                continue        # preempted by a later-iterated stream's growth
            chunk = cs.tokens[cs.start: cs.start + self.chunk_len]
            if self.pager is not None:
                ok = self.pager.ensure(slot, cs.start + len(chunk))
                while not ok and (self._reclaim_cached()
                                  or self._preempt_for_pages(exclude=slot)):
                    ok = self.pager.ensure(slot, cs.start + len(chunk))
                if not ok:
                    continue             # stall this chunk; retry next block
            bucket = next(b for b in self.buckets if b >= len(chunk))
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :len(chunk)] = chunk
            pt_row = None
            if self.pager is not None:
                pt_row = self._pt_rows(slot, cs.start + bucket)
            self._tok, self.caches, self._pos = \
                _chunk_prefill_kernel(
                    self.cfg, self._shd, bool(self._sampled_host[slot]),
                    self.params, jnp.asarray(padded),
                    jnp.asarray(cs.start, jnp.int32),
                    jnp.asarray(len(chunk), jnp.int32),
                    self.caches, jnp.asarray(slot, jnp.int32), pt_row,
                    self._tok, self._pos, self._keys, self._temps,
                    self._topk, self._topp)
            # resumed streams keep their original prefill_start/first_token
            t0 = self.vtime
            self._account_prefill_tokens(
                len(chunk), not cs.billed and cs.resume_tok is None, cs.req)
            cs.billed = True
            if self.tracer is not None:
                self.tracer.span("prefill_chunk", cs.req.rid, t0, self.vtime,
                                 self.name, chunk_start=cs.start,
                                 tokens=len(chunk))
            cs.start += len(chunk)
            self._register_prefix(cs.req, slot, cs.start)
            progressed = True
            if cs.start >= len(cs.tokens):
                finished.append(slot)
        for slot in finished:
            cs = self.prefilling.pop(slot, None)
            if cs is None:
                continue        # preempted after its last chunk this round:
                #                 the request recomputes from the queue head
            if cs.resume_tok is not None:
                # recomputed stream: next token was already sampled before
                # preemption; restore it instead of the chunk's provisional
                self._tok = self._row_pin(self._tok.at[slot].set(cs.resume_tok))
                self._start_stream(cs.req, slot, cs.resume_tok,
                                   len(cs.tokens), resumed=True)
            else:
                cs.req.first_token = self.vtime
                self._start_stream(cs.req, slot, int(self._tok[slot]),
                                   len(cs.tokens))
        if progressed:
            self._publish_metrics()
        return progressed

    def _preempt_for_pages(self, exclude: Optional[int] = None) -> bool:
        """Free the youngest stream's pages and requeue it for recompute-on-
        resume (emitted tokens are replayed through chunked prefill).

        Victims are chosen youngest-first by admission order across *both*
        phases: decoding streams and mid-chunked-prefill streams — a pool
        full of prefilling streams must not deadlock a grower (``exclude``
        keeps a chunk from preempting itself).  A preempted mid-prefill
        stream discards its chunk progress entirely; its request re-enters
        the queue head and re-admits when pages free up.  Returns False when
        there is nothing (else) to preempt.
        """
        order = {s: st.order for s, st in self.active.items()}
        order.update({s: cs.order for s, cs in self.prefilling.items()
                      if s != exclude})
        if not order:
            return False
        slot = max(order, key=order.get)
        if slot in self.active:
            req = self.active.pop(slot).req
        else:
            req = self.prefilling.pop(slot).req
        self._release_slot(slot)
        self.pending.insert(0, req)
        self._preempted += 1
        req.state = RequestState.QUEUED
        self._emit(StateEvent(req.rid, self.vtime, RequestState.QUEUED))
        if self._m is not None:
            self._m["ev"]["preempted"].inc()
        if self.tracer is not None:
            self.tracer.instant("preempt", req.rid, self.vtime, self.name)
        return True

    # -- cancellation / failure ------------------------------------------------
    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it currently lives — queued,
        mid-chunked-prefill, or mid-decode — freeing its slot and page chain
        immediately (the preemption machinery minus the requeue/recompute).
        The recurrent row state is frozen by the inactive mask and the freed
        pages' future held-pos writes land in the scratch page, so surviving
        streams are untouched.  Returns False for unknown or already-terminal
        requests; operates at block granularity like every host-side
        decision (no mid-block aborts, no new host syncs)."""
        return self._terminate(rid, RequestState.CANCELLED)

    def fail(self, rid: int) -> bool:
        """Give up on a request (``Backend.fail``): same clean release as
        ``cancel`` but the terminal state is FAILED — the system's verdict
        (watchdog wall-budget breach, stuck backend, crash cleanup), not the
        caller's.  Tokens already emitted stay readable."""
        return self._terminate(rid, RequestState.FAILED)

    def _terminate(self, rid: int, state: RequestState) -> bool:
        for i, req in enumerate(self.pending):
            if req.rid == rid:
                self.pending.pop(i)
                return self._mark_terminal(req, state)
        for slot, cs in list(self.prefilling.items()):
            if cs.req.rid == rid:
                del self.prefilling[slot]
                self._release_slot(slot)
                return self._mark_terminal(cs.req, state)
        for slot, st in list(self.active.items()):
            if st.req.rid == rid:
                del self.active[slot]
                self._release_slot(slot)
                return self._mark_terminal(st.req, state)
        return False

    def _release_slot(self, slot: int) -> None:
        """Return a slot (and its page chain) to the free pool and drop its
        batch row from the active mask."""
        if self.pager is not None:
            self.pager.free_chain(slot)
        self._active_host[slot] = False
        self._sampled_host[slot] = False
        self._sync_active()
        self.free_slots.append(slot)

    def _mark_terminal(self, req: Request, state: RequestState) -> bool:
        req.state = state
        if state is RequestState.CANCELLED:
            self._cancelled += 1
            kind = "cancelled"
        else:
            self._failed += 1
            kind = "failed"
        self._emit(StateEvent(req.rid, self.vtime, state))
        if self._m is not None:
            self._m["ev"][kind].inc()
        if self.tracer is not None:
            self.tracer.instant("cancel" if kind == "cancelled" else "fail",
                                req.rid, self.vtime, self.name,
                                tokens_emitted=req.tokens_emitted)
        return True

    def _evict_lapsed(self) -> None:
        """Deadline-aware eviction of *admitted* decoding streams (opt-in
        via ``EngineConfig.evict_lapsed``): a stream whose absolute deadline
        has lapsed mid-decode is freed through the same release path as
        ``cancel`` and reported SHED — every further token it would emit is
        guaranteed-late, so its slot, pages, and energy go to streams that
        can still pass.  Block-granular like every host-side decision;
        survivors are untouched (freed pages' held-position writes land in
        the scratch page)."""
        if not self.ecfg.evict_lapsed:
            return
        for slot, st in list(self.active.items()):
            req = st.req
            if req.deadline >= 0 and self.vtime > req.deadline + 1e-12:
                del self.active[slot]
                self._release_slot(slot)
                self._mark_shed(req)

    def _mark_shed(self, req: Request) -> None:
        req.state = RequestState.SHED
        self._shed += 1
        self._emit(StateEvent(req.rid, self.vtime, RequestState.SHED))
        if self._m is not None:
            self._m["ev"]["shed"].inc()
        if self.tracer is not None:
            self.tracer.instant("shed", req.rid, self.vtime, self.name,
                                deadline=req.deadline)

    # -- replica-to-replica migration (disaggregated serving) ------------------
    def export_stream(self, slot: int) -> StreamHandoff:
        """Extract an active (decodable) stream for adoption by another
        engine: the live page-chain K/V, bounded dense rows (sliding-window
        rings), recurrent SSM/RG-LRU row state, position and last sampled
        token.  The slot, its pages, and the batch row are released here —
        export is atomic from this engine's point of view: after it returns,
        the stream has no residue on this replica beyond scratch-page writes
        by the (now inactive) batch row.

        Only host-visible state at block granularity is touched, so exports
        ride the existing block cadence; the copied data is O(context), never
        a full-length buffer.
        """
        st = self.active.pop(slot)
        self._active_host[slot] = False
        self._sampled_host[slot] = False
        self._sync_active()
        self.free_slots.append(slot)
        chain = list(self.pager.chains.get(slot, [])) \
            if self.pager is not None else []
        blocks = []
        for stage in self.caches:
            sblocks = []
            for d in stage:
                if is_paged(d):
                    sblocks.append(("pages", paged_chain_extract(d, chain)
                                    if chain else None))
                else:
                    sblocks.append(("row", cache_row_extract(d, slot)))
            blocks.append(tuple(sblocks))
        if self.pager is not None:
            self.pager.export_chain(slot)
        # snapshot the *resolved* sampling config (None temperature becomes
        # an explicit 0.0): the handoff is the stream's complete decodable
        # state, so the adopter never re-resolves anything
        sp = st.req.sampling
        if sp is None or sp.temperature is None:
            temp, top_k, top_p = self._resolve_sampling(st.req)
            sp = SamplingParams(
                max_tokens=sp.max_tokens if sp else st.req.output_len,
                temperature=temp, top_k=top_k, top_p=top_p,
                seed=sp.seed if sp else None)
        if self._m is not None:
            self._m["ev"]["exported"].inc()
        if self.tracer is not None:
            self.tracer.instant("handoff_export", st.req.rid, self.vtime,
                                self.name, pages=len(chain), pos=st.pos)
        return StreamHandoff(
            req=st.req, pos=st.pos, last_token=st.last_token,
            n_pages=len(chain), blocks=blocks, export_time=self.vtime,
            page_size=self.ecfg.page_size if self.pager is not None else 0,
            cfg_name=self.cfg.name, sampling=sp,
            rng_lane=self._lane_for(st.req),
            ledger_carry=self.ledger.export_carry(self.name, st.req.rid)
            if self.ledger is not None else None,
            mesh_shape=self.ecfg.mesh)

    def import_stream(self, ho: StreamHandoff) -> bool:
        """Adopt a migrated stream: allocate a slot + an equal-length page
        chain, scatter the extracted pages/rows in, and join the decode
        batch at the handed-off position and token.  All-or-nothing: returns
        False — taking nothing — when no slot is free or the pool cannot
        cover the chain (the caller retries after streams retire).
        """
        assert ho.cfg_name == self.cfg.name, (
            f"cross-model handoff: {ho.cfg_name} -> {self.cfg.name}")
        assert ho.mesh_shape == self.ecfg.mesh, (
            f"cross-mesh handoff: exporter mesh {ho.mesh_shape} -> adopter "
            f"mesh {self.ecfg.mesh}; replicas in one cluster must share a "
            "mesh shape (handoff blocks are extracted per-shard-agnostic, "
            "but mixed shapes break the bit-exactness contract)")
        if ho.n_pages:
            assert self.pager is not None and \
                ho.page_size == self.ecfg.page_size, \
                "handoff requires matching paged layouts on both replicas"
        if not self.free_slots:
            return False
        slot = self.free_slots[0]
        chain = None
        if ho.n_pages:
            chain = self.pager.adopt_chain(slot, ho.n_pages)
            if chain is None and self._reclaim_cached():
                chain = self.pager.adopt_chain(slot, ho.n_pages)
            if chain is None:
                return False
        self.free_slots.pop(0)
        caches = []
        for stage, hstage in zip(self.caches, ho.blocks):
            sblocks = []
            for d, (kind, payload) in zip(stage, hstage):
                if kind == "pages":
                    sblocks.append(paged_chain_insert(d, payload, chain)
                                   if payload is not None else d)
                else:
                    sblocks.append(cache_row_insert(d, payload, slot))
            caches.append(tuple(sblocks))
        self.caches = self._pin_caches(caches)
        self._tok = self._row_pin(self._tok.at[slot].set(ho.last_token))
        self._pos = self._row_pin(self._pos.at[slot].set(ho.pos))
        # the RNG lane and the exporter-resolved sampling config travel with
        # the stream: the adopter continues the exporter's draw sequence and
        # sampling mode instead of re-resolving against its own defaults
        # (draw i is fold_in(lane, position i), so this is all the state
        # needed)
        if ho.rng_lane is not None:
            ho.req.rng_lane = np.asarray(ho.rng_lane, np.uint32)
        if ho.sampling is not None:
            ho.req.sampling = ho.sampling
        self._set_slot_sampling(slot, ho.req)
        # the adopted chain's prompt pages are this pool's bits now: re-share
        # them so later arrivals with the same prompt hit on this replica too
        self._register_prefix(ho.req, slot, ho.pos)
        if self.ledger is not None:
            # no-op when the exporter billed into this same ledger (the
            # cluster shares one); across distinct ledgers the request's
            # partial attribution travels with the stream
            self.ledger.adopt_carry(ho.ledger_carry, ho.req.rid)
        self._imported += 1
        self.requests.append(ho.req)
        if self._m is not None:
            self._m["ev"]["imported"].inc()
        if self.tracer is not None:
            # the span covers the stream's in-flight window between replicas
            # (clamped: the adopter's clock may lag the exporter's)
            self.tracer.span("handoff", ho.req.rid, ho.export_time,
                             max(self.vtime, ho.export_time), self.name,
                             pages=ho.n_pages, pos=ho.pos)
        self._start_stream(ho.req, slot, ho.last_token, ho.pos, resumed=True)
        return True

    # -- decode ----------------------------------------------------------------
    def _account_decode_step(self, batch: int, ctx: float, dur=None,
                             rids=None) -> float:
        f = self.controller.maybe_tick(self.vtime)
        if dur is None:
            dur = self.plant.decode_step_latency(batch, ctx, f)
        e = dur * self.plant.decode_power(batch, ctx, f, dur)
        self.energy_j += e
        self.decode_energy_j += e
        self.decode_tokens += batch
        self.vtime += dur
        if rids is not None:
            # each alive row produced exactly one token this step, so
            # "shared by tokens produced" is an equal per-rid split
            self.ledger.record_decode(
                self.name, rids, e,
                saved_j=self._cf.decode_j(batch, ctx) - e)
        self.controller.record_tokens(self.vtime, batch, dur)
        return dur

    def _finish_check(self, st: _Stream) -> bool:
        """Mark a stream finished when it has emitted its budget (or hit
        max_len).  The FINISHED StateEvent is emitted by the caller *after*
        the stream's TokenEvent so drain_events consumers never see
        end-of-stream before the final tokens."""
        if (st.req.tokens_emitted >= st.req.output_len
                or st.pos >= self.ecfg.max_len - 1):
            st.req.finish = self.vtime
            st.req.state = RequestState.FINISHED
            self._completed += 1
            return True
        return False

    def _retire(self, slots: List[int]):
        for slot in slots:
            self.free_slots.append(slot)
            del self.active[slot]
            self._active_host[slot] = False
            self._sampled_host[slot] = False
            if self.pager is not None:
                self.pager.free_chain(slot)   # whole chain back to the pool
        if slots:
            self._sync_active()

    def _grow_for_block(self, k: int) -> int:
        """Grow every active chain to cover ``pos + k`` before the block is
        dispatched (the in-scan writes must stay inside allocated pages).
        Shrinks ``k``, then preempts youngest streams, if the pool runs dry.
        """
        while True:
            ordered = sorted(self.active.items(),
                             key=lambda kv: kv[1].order)   # oldest first
            if all(self.pager.ensure(s, st.pos + k) for s, st in ordered):
                return k
            if self._reclaim_cached():
                continue        # cache-only pages go before k shrinks or
                #                 anything live is preempted
            if k > 1:
                k = max(k // 2, 1)
                continue
            if len(self.active) + len(self.prefilling) > 1:
                self._preempt_for_pages()
                continue
            raise RuntimeError(
                "page pool exhausted: a lone stream cannot grow by one page "
                f"({self.pager.pages_used}/{self.pager.num_pages - 1} used)")

    def _decode_block(self, k: int) -> int:
        """Run ``k`` decode steps with a single host drain at the end;
        returns the number of steps actually executed (pool pressure may
        shrink ``k``).

        The batch composition is fixed for the block (the caller sizes ``k``
        to the next join/leave event), so virtual-time accounting needs no
        device data and the jitted steps pipeline without a host sync.
        Stream events (tokens, finishes) are emitted here, once per block —
        the streaming API inherits the no-per-token-host-sync invariant.
        """
        if self.pager is not None and self.active:
            k = self._grow_for_block(k)
        snapshot = list(self.active.items())
        batch = len(snapshot)
        if batch == 0:
            return 0
        max_pos = max(st.pos for st in self.active.values())
        if self.prefilling:
            # mid-prefill rows are inactive but still receive the held-pos
            # write each step; the ctx bucket (cache slice / page-table
            # slice) must cover their positions or that write wraps onto
            # position pos % ctx and corrupts their already-written context
            max_pos = max(max_pos,
                          max(cs.start for cs in self.prefilling.values()))
        wall = self.ecfg.use_wall_clock
        # host-known static: does any *decoding* row sample?  All-greedy
        # blocks compile (and run) without the sampler's per-step sort, and
        # a sampled stream that is still mid-chunked-prefill (inactive, its
        # draws masked anyway) doesn't force the sampled kernel variant.
        # Computed from stream metadata at block granularity — no device
        # read.
        sampled = bool(self._sampled_host[self._active_host].any())
        toks_dev = []
        durs: List[Optional[float]] = []   # per-step; None -> plant model
        left = k
        while left > 0:
            # fill the current ctx bucket before stepping up to the next one:
            # attention cost is O(ctx), so prefer many steps at small ctx
            ctx = next((c for c in self.ctx_buckets if c > max_pos),
                       self.ecfg.max_len)
            room = max(ctx - max_pos, 1)
            kb = next((b for b in self._k_blocks if b <= min(left, room)), 1)
            t0 = time.perf_counter() if wall else 0.0
            if self.pager is not None:
                n_ctx = min(ctx // self.ecfg.page_size, self._max_pages)
                pt = self.pager.table_device()[:, :n_ctx]
                (self._tok, self.caches, self._pos, tk) = \
                    _paged_decode_block_kernel(
                        self.cfg, self._shd, kb, sampled,
                        self.params, self._tok, self.caches, pt, self._pos,
                        self._active, self._keys, self._temps, self._topk,
                        self._topp)
            else:
                (self._tok, self.caches, self._pos, tk) = \
                    _decode_block_kernel(
                        self.cfg, self._shd, ctx, kb, self.ecfg.max_len,
                        sampled,
                        self.params, self._tok, self.caches, self._pos,
                        self._active, self._keys, self._temps, self._topk,
                        self._topp)
            toks_dev.append(tk)        # (kb, B) device, drained at block end
            if wall:
                # wall-clock mode syncs per chunk (still amortized over kb
                # steps); a kernel's first chunk includes compile time, so
                # bill those steps to the plant model instead
                jax.block_until_ready(tk)
                seen = (ctx, kb) in self._warmed
                self._warmed.add((ctx, kb))
                dt = (time.perf_counter() - t0) / kb
                durs.extend([dt if seen else None] * kb)
            else:
                durs.extend([None] * kb)
            max_pos += kb
            left -= kb
        # single drain per block: (k, B) int32
        self._host_drains += 1
        toks = np.concatenate(jax.device_get(toks_dev), axis=0)
        t_block = self.vtime
        done: List[int] = []
        block_toks: Dict[int, List[int]] = {slot: [] for slot, _ in snapshot}
        for i in range(k):
            ctx = float(np.mean([st.pos for st in self.active.values()
                                 if st.slot not in done]))
            alive = batch - len(done)
            rids = None if self.ledger is None else \
                [st.req.rid for slot, st in snapshot if slot not in done]
            dur = self._account_decode_step(alive, ctx, durs[i], rids)
            if self._m is not None:
                # one bucketed observation per step, weighted by the rows
                # that shared it — exact, without alive python calls
                self._m["tbt"].observe(dur, alive)
                self._obs_tbt.record_tbt(self.vtime, dur)
            for slot, st in snapshot:
                if slot in done:
                    continue
                st.last_token = int(toks[i, slot])
                st.req.tokens.append(st.last_token)
                block_toks[slot].append(st.last_token)
                st.pos += 1
                st.req.tokens_emitted += 1
                self._tbt.setdefault(st.req.rid, []).append(dur)
                if self._finish_check(st):
                    done.append(slot)
                    self._obs_finish(st.req)
                    if self.tracer is not None:
                        self.tracer.instant(
                            "finish", st.req.rid, self.vtime, self.name,
                            tokens=st.req.tokens_emitted)
        for slot, st in snapshot:       # one TokenEvent per stream per block
            if block_toks[slot]:
                self._emit(TokenEvent(
                    st.req.rid, self.vtime, tuple(block_toks[slot]),
                    len(block_toks[slot])))
        by_slot = dict(snapshot)        # FINISHED strictly after the tokens
        for slot in done:
            self._emit(StateEvent(by_slot[slot].req.rid, self.vtime,
                                  RequestState.FINISHED))
        self._retire(done)
        if self.pager is not None:
            # occupancy_live excludes cache-only (evictable) pages: a pool
            # full of reclaimable prefixes is not memory pressure, and the
            # controller's occ_high bias must not chase it.  Bitwise equal
            # to raw occupancy whenever the cache holds nothing.
            occ = self.pager.occupancy()["occupancy_live"]
            self._occupancy.record(self.vtime, occ)
            # memory pressure is a controller input: sustained high pool
            # occupancy biases the coarse loop toward higher clocks so
            # streams drain before the pool forces preemption
            record = getattr(self.controller, "record_occupancy", None)
            if record is not None:
                record(self.vtime, occ)
        if self.tracer is not None:
            self.tracer.span("decode_block", -1, t_block, self.vtime,
                             self.name, steps=k, batch=batch,
                             freq_mhz=self.controller.freq)
        self._publish_metrics()
        return k

    def _step_legacy(self) -> int:
        """Pre-slot data plane: host argmax + batch-wide max(pos).  Kept only
        as the benchmark baseline; wrong for mixed-position batches."""
        B = self.ecfg.max_batch
        tok = np.zeros((B, 1), np.int32)
        for slot, st in self.active.items():
            tok[slot, 0] = st.last_token
        pos = max(st.pos for st in self.active.values())
        logits, self.caches = _decode_legacy_kernel(
            self.cfg, self.params, jnp.asarray(tok), self.caches,
            jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        batch = len(self.active)
        ctx = float(np.mean([st.pos for st in self.active.values()]))
        rids = None if self.ledger is None else \
            [st.req.rid for st in self.active.values()]
        dur = self._account_decode_step(batch, ctx, rids=rids)
        done = []
        for slot, st in self.active.items():
            st.last_token = int(nxt[slot])
            st.req.tokens.append(st.last_token)
            st.pos += 1
            st.req.tokens_emitted += 1
            self._tbt.setdefault(st.req.rid, []).append(dur)
            self._emit(TokenEvent(st.req.rid, self.vtime,
                                  (st.last_token,), 1))
            if self._finish_check(st):
                self._emit(StateEvent(st.req.rid, self.vtime,
                                      RequestState.FINISHED))
                done.append(slot)
        self._retire(done)
        return batch

    def has_work(self) -> bool:
        """Backend protocol: anything queued, mid-prefill, or decoding."""
        return bool(self.pending or self.prefilling or self.active)

    def drain_events(self) -> List:
        """Backend protocol: hand out (and clear) the buffered stream
        events.  Events accumulate at block granularity — draining them is
        a host-side list swap, never a device sync."""
        ev, self._events = self._events, []
        return ev

    def _advance_idle(self) -> bool:
        """Nothing running and the FIFO head not yet arrived: jump the
        virtual clock to the *head's* arrival, billing the gap at idle
        power (same accounting as a cluster replica waiting on arrivals).
        The head — not the minimum over the queue — because ``_admit`` is
        strictly FIFO by submission order: jumping to a later-submitted
        earlier arrival would leave the head still unadmittable and
        deadlock the driver."""
        if not self.pending:
            return False
        head = self.pending[0]
        nxt = max(head.arrival, head.not_before)
        if nxt <= self.vtime + 1e-12:
            return False
        e_idle = (nxt - self.vtime) * self.plant.idle_power
        self.idle_energy_j += e_idle
        if self.ledger is not None:
            self.ledger.record_idle(self.name, e_idle)
        self.vtime = nxt
        self._publish_metrics()
        return True

    def step(self, k: Optional[int] = None) -> int:
        """One scheduling round: admit arrived requests, advance chunked
        prefills, then decode a block of ``k`` steps (default: the horizon
        to the next guaranteed join/leave event).  Returns the number of
        decode steps executed — 0 for admission/chunk/idle-only rounds.

        This is the ``Backend.step`` entry point: the ``serving.api``
        driver loop calls it with no argument; pass ``k=1`` for
        single-step-granularity tests."""
        self._evict_lapsed()     # opt-in: lapsed decoders free slots first
        self._admit()
        progressed = False
        if self.ecfg.slot_native:
            progressed = self._advance_chunks()
        if not self.active:
            if progressed or self._advance_idle():
                return 0
            if self.prefilling or self.pending:
                raise RuntimeError(
                    "serving stalled: pending/prefilling streams cannot "
                    "obtain pages or slots and nothing is decoding")
            return 0
        if not self.ecfg.slot_native:
            self._step_legacy()
            return 1
        # clamp to the horizon: _decode_block's batch composition is fixed
        # for the whole block, so k may never cross a guaranteed leave event
        horizon = self._horizon()
        return self._decode_block(max(min(k, horizon) if k is not None
                                      else horizon, 1))

    def _horizon(self) -> int:
        """Steps until the next guaranteed stream leave (no joins possible:
        the caller admits first).  Capped at ``decode_block`` — which also
        bounds how long a mid-prefill stream waits for its next chunk."""
        rem_out = min(max(st.req.output_len - st.req.tokens_emitted, 1)
                      for st in self.active.values())
        rem_len = min(self.ecfg.max_len - 1 - st.pos
                      for st in self.active.values())
        return max(1, min(rem_out, rem_len, self.ecfg.decode_block))

    @property
    def now(self) -> float:
        """Backend protocol: the engine's current virtual time (the clock
        the ``Server.run`` watchdog compares request wall-budgets against)."""
        return self.vtime

    def effective_prefill_tokens(self, req: Request) -> int:
        """Prefill tokens this engine would actually *compute* for ``req``:
        the prompt length minus the currently-cached prefix (a pure probe —
        no counters, no LRU touch).  ``PrefillOptimizer.busy_time`` and the
        cluster's routing/retuning consume this so clock selection and
        placement see the real work, not the nominal prompt length.  Exactly
        ``req.prompt_len`` whenever caching is off or the prompt tokens are
        not yet materialized."""
        if not self._cacheable or req.prompt is None:
            return req.prompt_len
        return max(req.prompt_len - self.prefix_cache.probe(req.prompt), 1)

    def page_occupancy_peak(self) -> float:
        """Peak page-pool occupancy over the run (0 when unpaged)."""
        if self.pager is None:
            return 0.0
        live = {sl: st.pos for sl, st in self.active.items()}
        live.update({sl: cs.start for sl, cs in self.prefilling.items()})
        return self.pager.occupancy(live)["peak_occupancy"]

    def report(self) -> ServingReport:
        """Backend protocol: the typed serving report (single scoring
        definition shared with the cluster and the simulator)."""
        peak = self.page_occupancy_peak()
        led = {}
        if self.ledger is not None:
            led = dict(energy_by_rid=self.ledger.energy_by_rid(),
                       saved_by_rid=self.ledger.saved_by_rid(),
                       energy_saved_j=self.ledger.replica_saved_j(self.name))
        return build_report(
            backend="engine", requests=self.requests, tbt_records=self._tbt,
            slo=self.ecfg.slo, class_names=self.router.class_names,
            prefill_energy_j=self.prefill_energy_j,
            decode_energy_j=self.decode_energy_j,
            idle_energy_j=self.idle_energy_j,
            prefill_tokens=self.prefill_tokens,
            decode_tokens=self.decode_tokens,
            duration_s=self.vtime, preempted=self._preempted,
            # adopted handoffs only, matching the cluster-level definition
            # (summing imports counts each migration exactly once)
            migrated=self._imported,
            page_occupancy_peak=peak, **led)

    def _slo_stats(self) -> Dict:
        """Per-class p90 TTFT and TTFT/TBT SLO pass rates —
        ``core.report.slo_pass_metrics`` is the single scoring definition,
        applied to the same population as ``report()`` (every request with
        a first token, cancelled included), so the legacy dict and the
        typed report can never diverge."""
        from repro.core.report import slo_pass_metrics
        m = slo_pass_metrics(self.requests, self._tbt, self.ecfg.slo,
                             self.router.class_names)
        return {"ttft_pass": m["ttft_pass"], "tbt_pass": m["tbt_pass"],
                "p90_ttft_s": m["p90_ttft"]}

    def stats(self) -> Dict:
        tbts = [x for v in self._tbt.values() for x in v]
        s = {
            "completed": self._completed,
            "cancelled": self._cancelled,
            "failed": self._failed,
            "shed": self._shed,
            "pending": len(self.pending),
            "active": len(self.active),
            "prefilling": len(self.prefilling),
            "vtime_s": self.vtime,
            # active + idle, matching the cluster's legacy dict (idle is 0
            # for batch workloads; billed only while waiting on arrivals)
            "energy_j": self.energy_j + self.idle_energy_j,
            "idle_energy_j": self.idle_energy_j,
            # per-phase split, comparable with sim.replay.Metrics
            "prefill_energy_j": self.prefill_energy_j,
            "decode_energy_j": self.decode_energy_j,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "p95_tbt_ms": float(np.percentile(tbts, 95)) * 1e3 if tbts else 0,
            "p99_tbt_ms": float(np.percentile(tbts, 99)) * 1e3 if tbts else 0,
            "freq_mhz": self.controller.freq,
        }
        s.update(self._slo_stats())
        if self.pager is not None:
            # a stream at position pos holds K/V for positions 0..pos-1
            live = {sl: st.pos for sl, st in self.active.items()}
            live.update({sl: cs.start for sl, cs in self.prefilling.items()})
            occ = self.pager.occupancy(live)
            s.update({
                "pages_used": occ["pages_used"],
                "pages_total": occ["pages_total"],
                "pages_shared": occ["pages_shared"],
                "pages_reserved": occ["pages_reserved"],
                "pages_cached": occ["pages_cached"],
                "page_occupancy": occ["occupancy"],
                "page_occupancy_live": occ["occupancy_live"],
                "page_occupancy_peak": occ["peak_occupancy"],
                "page_fragmentation": occ["fragmentation"],
                "preempted": self._preempted,
            })
        if self.prefix_cache is not None:
            pc = self.prefix_cache.stats()
            s.update({f"prefix_cache_{k}": v for k, v in pc.items()})
        return s
