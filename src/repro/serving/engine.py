"""Real-execution serving engine: actual JAX prefill/decode with continuous
batching, driven by the same GreenLLM control plane as the simulator.

This is the integration layer that proves the controllers compose with the
real model code: requests are tokenized (synthetic ids), routed by length,
prefilled (real ``models.prefill``), then decoded step-by-step in a batched
loop (real ``models.decode_step``) with stream join/leave between steps.

On this CPU container the engine runs reduced models; *virtual time* for
SLO/energy accounting comes from the calibrated plant model (wall-clock CPU
time of a smoke-scale model says nothing about an A100/TPU), while the token
*values* are produced by the real network.  On real hardware, set
``use_wall_clock=True`` and the controllers consume measured latencies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DualLoopController, MaxFreqController, Request,
                        SLOConfig, make_router)
from repro.models import ModelConfig, init_cache, init_params, prefill, decode_step
from repro.sim import PlantModel
from repro.sim.profiling import profile_decode_table
from repro.core.hardware import HardwareProfile, A100_SXM4_40G


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_len: int = 256
    greedy: bool = True
    governor: str = "greenllm"      # greenllm | defaultnv
    use_wall_clock: bool = False


class _Stream:
    def __init__(self, req: Request, slot: int, last_token: int, pos: int):
        self.req = req
        self.slot = slot
        self.last_token = last_token
        self.pos = pos
        self.tokens: List[int] = []


class ServingEngine:
    """Batched decode over a shared slotted KV cache (continuous batching)."""

    def __init__(self, cfg: ModelConfig, params=None, *,
                 ecfg: EngineConfig = EngineConfig(),
                 hw: HardwareProfile = A100_SXM4_40G, seed: int = 0,
                 plant_cfg: ModelConfig = None):
        # plant_cfg: config used for virtual-time/energy accounting (e.g. the
        # FULL model) while `cfg` (possibly reduced) produces real tokens.
        self.cfg = cfg
        self.ecfg = ecfg
        self.params = params if params is not None else init_params(
            jax.random.PRNGKey(seed), cfg)
        self.router = make_router(ecfg.governor.lower() != "defaultnv")
        self.plant = PlantModel(cfg=plant_cfg or cfg, hw=hw, n_chips=1,
                                seed=seed)
        if ecfg.governor.lower() == "greenllm":
            table = profile_decode_table(self.plant)
            self.controller = DualLoopController(hw, table)
        else:
            self.controller = MaxFreqController(hw)
        self.caches = init_cache(cfg, ecfg.max_batch, ecfg.max_len)
        self.active: Dict[int, _Stream] = {}
        self.free_slots = list(range(ecfg.max_batch))
        self.pending: List[Request] = []
        self.vtime = 0.0
        self.energy_j = 0.0
        self._tbt: Dict[int, List[float]] = {}

        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(p, cfg, t, c, pos))

    # -- request intake --------------------------------------------------------
    def submit(self, req: Request, prompt_tokens: Optional[np.ndarray] = None):
        req.cls = self.router.class_names[self.router.classify(req.prompt_len)]
        if prompt_tokens is None:
            rng = np.random.default_rng(req.rid)
            prompt_tokens = rng.integers(
                0, self.cfg.vocab_size, size=max(req.prompt_len, 1))
        req._prompt = np.asarray(prompt_tokens)[-self.ecfg.max_len // 2:]
        self.pending.append(req)

    def _admit(self):
        while self.pending and self.free_slots:
            req = self.pending.pop(0)
            slot = self.free_slots.pop(0)
            toks = jnp.asarray(req._prompt, jnp.int32)[None]
            caches = init_cache(self.cfg, 1, self.ecfg.max_len)
            logits, caches, pos = prefill(self.params, self.cfg, toks, caches)
            # splice the single-request cache into the batch cache at `slot`
            self.caches = jax.tree.map(
                lambda full, one: full.at[:, slot:slot + 1].set(one)
                if full.ndim >= 2 else full, self.caches, caches)
            tok = int(jnp.argmax(logits[0]))
            t_pf = self.plant.prefill_latency(req.prompt_len, self.controller.freq)
            p_pf = self.plant.prefill_power(req.prompt_len,
                                            self.controller.freq, t_pf)
            self.energy_j += t_pf * p_pf
            self.vtime += t_pf
            req.prefill_start = self.vtime - t_pf
            req.first_token = self.vtime
            st = _Stream(req, slot, tok, len(req._prompt))
            st.tokens.append(tok)
            req.tokens_emitted = 1
            self.active[slot] = st

    # -- one decode step over all active streams ----------------------------------
    def step(self) -> int:
        self._admit()
        if not self.active:
            return 0
        B = self.ecfg.max_batch
        tok = np.zeros((B, 1), np.int32)
        for slot, st in self.active.items():
            tok[slot, 0] = st.last_token
        pos = max(st.pos for st in self.active.values())
        logits, self.caches = self._decode(self.params, jnp.asarray(tok),
                                           self.caches, jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        batch = len(self.active)
        ctx = float(np.mean([st.pos for st in self.active.values()]))
        f = self.controller.maybe_tick(self.vtime)
        dur = self.plant.decode_step_latency(batch, ctx, f)
        self.energy_j += dur * self.plant.decode_power(batch, ctx, f, dur)
        self.vtime += dur
        self.controller.record_tokens(self.vtime, batch, dur)
        done = []
        for slot, st in self.active.items():
            st.last_token = int(nxt[slot])
            st.tokens.append(st.last_token)
            st.pos += 1
            st.req.tokens_emitted += 1
            self._tbt.setdefault(st.req.rid, []).append(dur)
            if (st.req.tokens_emitted >= st.req.output_len
                    or st.pos >= self.ecfg.max_len - 1):
                st.req.finish = self.vtime
                done.append(slot)
        for slot in done:
            self.free_slots.append(slot)
            del self.active[slot]
        return batch

    def run_until_drained(self, max_steps: int = 10_000) -> Dict:
        steps = 0
        while (self.pending or self.active) and steps < max_steps:
            if self.step() == 0 and not self.pending:
                break
            steps += 1
        return self.stats()

    def stats(self) -> Dict:
        reqs = list(self._tbt)
        tbts = [x for v in self._tbt.values() for x in v]
        return {
            "completed": len(reqs),
            "vtime_s": self.vtime,
            "energy_j": self.energy_j,
            "p95_tbt_ms": float(np.percentile(tbts, 95)) * 1e3 if tbts else 0,
            "freq_mhz": self.controller.freq,
        }
