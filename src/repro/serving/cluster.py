"""Disaggregated prefill/decode serving cluster with paged-KV handoff and
per-phase DVFS.

GreenLLM's core observation — prefill is compute-bound, decode memory-bound,
so they deserve *separate* frequency control — extends naturally to separate
*placement* (DualScale, PAPERS.md): dedicated prefill and decode replicas,
each running its phase-optimal policy all the time, instead of one colocated
engine whose single clock chases whichever phase currently dominates.

Topology and control plane:

* **Replicas** are full ``ServingEngine`` instances sharing model params and
  one offline profiling pass, in ``role="prefill"``, ``"decode"`` or
  ``"colocated"``.  Prefill replicas only admit and chunk-prefill; their
  clock is set per step by the queueing-aware ``PrefillOptimizer`` over the
  replica's own queue (Eq. 14 with the deadline from the oldest queued
  request's TTFT budget).  Decode replicas only decode; each runs its own
  ``DualLoopController`` (with page-occupancy memory pressure).  Colocated
  replicas behave like the single-engine baseline.
* **Dispatch** (``ClusterDispatcher``, a ``LengthRouter``): requests are
  classified by prompt length, then routed to the candidate prefill replica
  with the shortest *expected ready time* — replica virtual clock plus
  ``PrefillOptimizer.busy_time`` of its queue at its current frequency
  (queueing-aware, not just shortest-queue).  Completed prefills migrate to
  the least-loaded decode replica.
* **Paged-KV handoff**: migration moves the stream's page-chain K/V,
  bounded dense rows, recurrent row state, position and last token via
  ``ServingEngine.export_stream`` / ``import_stream`` — O(context) data
  through ``PageAllocator.export_chain`` / ``adopt_chain``, never a
  full-length buffer.  The handoff is atomic: a stream lives on exactly one
  replica at any instant, and a failed import (no slot / no pages) takes
  nothing and retries after the decode replica drains.
* **Shared virtual clock**: every replica advances its engine's virtual time
  only while working; the cluster always steps the laggard replica next, so
  replica timelines interleave at decode-block granularity exactly like
  concurrently-running hardware.  A migrated stream may not start decoding
  before its export timestamp; idle gaps (a replica waiting on arrivals or
  on the other phase) are billed at the plant's idle power, and the run's
  makespan is the max over replica clocks — total energy is therefore
  directly comparable between disaggregated and colocated layouts at equal
  replica count.

``examples/serve_trace_replay.py --cluster`` replays azure/alibaba traces
through a 1 prefill + 1 decode cluster against a 2x-colocated max-frequency
baseline; ``benchmarks/serving_engine.py --cluster`` is the CI-sized smoke.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import (DualLoopController, DecodeControllerConfig,
                        LengthRouter, MaxFreqController, PrefillOptimizer,
                        ReplicaReport, Request, RequestState, ServingReport,
                        SLOConfig, StateEvent, build_report)
from repro.core.hardware import HardwareProfile, A100_SXM4_40G
from repro.core.prefill_optimizer import deadline_from_queue
from repro.models import ModelConfig, init_params
from repro.sim import PlantModel
from repro.sim.profiling import (profile_decode_table, profile_power,
                                 profile_prefill_latency)
from .engine import EngineConfig, ServingEngine, StreamHandoff
from .faults import FaultPlan

ROLES = ("prefill", "decode", "colocated")

# mirror sim.engine.PrefillWorker: reserve deadline headroom for dispatch +
# the first decode step, and protect against arrival burstiness
DEADLINE_SAFETY = 0.72
FIRST_TOKEN_RESERVE = 0.060  # s

# capped exponential backoff for failed StreamHandoff imports (virtual
# seconds): 1st retry after BASE, doubling to at most CAP.  The decode
# replica advances its clock (billing idle) to the earliest retry when it
# has nothing else to do, so a backed-off import can never stall the run.
HANDOFF_RETRY_BASE = 0.004
HANDOFF_RETRY_CAP = 0.128


class _PendingImport:
    """A queued ``StreamHandoff`` plus its retry state: ``next_try`` starts
    at the export timestamp (a stream may not start decoding before it was
    exported) and backs off exponentially on failed import attempts."""
    __slots__ = ("ho", "attempts", "next_try")

    def __init__(self, ho: StreamHandoff):
        self.ho = ho
        self.attempts = 0
        self.next_try = ho.export_time


class PrefillPhaseController(MaxFreqController):
    """Frequency holder for a prefill replica: the cluster writes the
    queueing-aware optimizer's choice into ``freq`` before each admission
    round and the engine bills prefill work at it.  Same surface as
    ``MaxFreqController`` (tick/record are no-ops) — prefill frequency is
    re-planned from the queue, not from telemetry."""


class ClusterDispatcher(LengthRouter):
    """``LengthRouter`` extended with queueing-aware replica selection.

    Classification (thresholds / class names) is inherited; the cluster adds
    two placement decisions on top:

    * ``pick_prefill``: among the replicas serving the request's class, the
      one whose *expected ready time* — virtual clock + optimizer-predicted
      busy time of its queue (plus this request) at its current clock — is
      smallest.  Falls back to shortest queue when no optimizer is
      configured (DefaultNV baseline).
    * ``pick_decode``: least streams in flight (active + queued imports),
      ties to the laggard clock — decode batching is capacity-driven, so
      stream count is the right load signal, not predicted latency.
    """

    def pick_prefill(self, req: Request, replicas: Sequence["Replica"],
                     optimizer: Optional[PrefillOptimizer]) -> "Replica":
        cls = self.class_names[self.classify(req.prompt_len)]
        cands = [r for r in replicas if not r.classes or cls in r.classes] \
            or list(replicas)
        if optimizer is None:
            return min(cands, key=lambda r: (r.queue_depth(), r.vtime))

        def expected_ready(r: "Replica") -> float:
            # effective_prefill_tokens: a replica whose prefix cache already
            # holds this prompt's pages owes less work for it — placement
            # and the busy-time clock plan see the computed tokens, not the
            # nominal prompt length (identical when caching is off)
            lengths = r.queued_lengths() + \
                [r.engine.effective_prefill_tokens(req)]
            return r.vtime + optimizer.busy_time(lengths, r.freq)

        return min(cands, key=expected_ready)

    def pick_decode(self, replicas: Sequence["Replica"]) -> "Replica":
        return min(replicas, key=lambda r: (r.streams_in_flight(), r.vtime))


class Replica:
    """One engine + its role, import queue, and idle-energy meter."""

    def __init__(self, name: str, role: str, engine: ServingEngine,
                 classes: Tuple[str, ...] = ()):
        assert role in ROLES, role
        self.name = name
        self.role = role
        self.engine = engine
        self.classes = classes          # prefill classes served (() = all)
        self.import_q: List[_PendingImport] = []
        self.idle_j = 0.0               # idle energy billed for clock jumps
        self.exported = 0
        self.imported = 0
        # fault tolerance: a dead replica is never stepped or dispatched to
        # again; its clock and energy freeze at the kill
        self.alive = True
        self.killed_at = -1.0

    @property
    def vtime(self) -> float:
        return self.engine.vtime

    @property
    def freq(self) -> float:
        return self.engine.controller.freq

    def queued_lengths(self) -> List[int]:
        """Prefill tokens still owed: queued prompts in full, in-flight
        chunked prefills by their remaining chunks."""
        e = self.engine
        return ([e.effective_prefill_tokens(r) for r in e.pending]
                + [max(len(cs.tokens) - cs.start, 0)
                   for cs in e.prefilling.values()])

    def queue_depth(self) -> int:
        return len(self.engine.pending) + len(self.engine.prefilling)

    def streams_in_flight(self) -> int:
        e = self.engine
        return len(e.active) + len(e.prefilling) + len(e.pending) \
            + len(self.import_q)

    def has_work(self) -> bool:
        e = self.engine
        return bool(e.pending or e.prefilling or e.active or self.import_q)

    def advance_to(self, t: float) -> None:
        """Move this replica's clock forward to ``t`` (waiting on an arrival
        or a migration), billing the gap at idle power.  Clocks never move
        backwards — the shared-clock invariant."""
        e = self.engine
        gap = t - e.vtime
        if gap > 0:
            e_idle = gap * e.plant.idle_power
            self.idle_j += e_idle
            e.vtime = t
            if e.ledger is not None:
                # mirror the identical float so the ledger's idle mirror
                # stays bitwise equal to this replica's idle_j accumulator
                e.ledger.record_idle(e.name, e_idle)
            if e._m is not None:
                # cluster idle is billed here, outside the engine's own
                # idle meter — publish it directly so per-replica energy
                # counters stay complete
                e._m["e_idle"].inc(e_idle)
                e._publish_metrics()


class ServingCluster:
    """Multi-replica serving cluster on a shared virtual clock.

    ``n_prefill``/``n_decode`` build a disaggregated layout (both > 0 — the
    phases need each other); ``n_colocated`` adds single-engine-style
    replicas (a pure colocated cluster is the baseline configuration).
    All replicas share ``params`` and one offline profiling pass; the paged
    slot-native data plane is forced because the handoff moves page chains.
    """

    def __init__(self, cfg: ModelConfig, *, n_prefill: int = 1,
                 n_decode: int = 1, n_colocated: int = 0, params=None,
                 ecfg: Optional[EngineConfig] = None,
                 hw: HardwareProfile = A100_SXM4_40G,
                 plant_cfg: ModelConfig = None,
                 slo: Optional[SLOConfig] = None, seed: int = 0,
                 faults: Optional[FaultPlan] = None,
                 metrics=None, tracer=None, ledger=None):
        assert n_prefill + n_decode + n_colocated > 0
        assert (n_prefill > 0) == (n_decode > 0), \
            "disaggregated roles come in pairs (prefill output needs a " \
            "decode replica and vice versa)"
        self.cfg = cfg
        self.hw = hw
        self.slo = slo if slo is not None else SLOConfig()
        base = ecfg if ecfg is not None else EngineConfig()
        greenllm = base.governor.lower() == "greenllm"
        # handoff moves page chains: force the paged slot-native plane.
        # ``base.mesh`` (if any) rides this single ecfg into every replica,
        # so all replicas serve on one mesh shape and stream handoffs never
        # cross meshes — ``import_stream`` asserts the shape match anyway,
        # making a mixed-mesh cluster fail loudly at the first migration.
        self.ecfg = dataclasses.replace(base, paged=True,
                                        chunked_prefill=True, slo=self.slo)
        if params is None:
            params = init_params(jax.random.PRNGKey(seed), cfg)
        pcfg = plant_cfg or cfg

        # one offline profiling pass shared by every replica (the paper's
        # microbenchmarks); per-replica controllers get table *copies* so
        # runtime band adaptation stays replica-local
        prof_plant = PlantModel(cfg=pcfg, hw=hw, n_chips=1, seed=seed + 999)
        self._table = None
        self.optimizer: Optional[PrefillOptimizer] = None
        if greenllm:
            deg = 1 if pcfg.is_subquadratic else 2
            lat = profile_prefill_latency(prof_plant, degree=deg)
            pwr = profile_power(prof_plant)
            self.optimizer = PrefillOptimizer(lat, pwr, hw, hw.p_idle)
            self._table = profile_decode_table(prof_plant,
                                               self.slo.tbt_target)
        self.dispatcher = ClusterDispatcher() if greenllm else \
            ClusterDispatcher(thresholds=(), class_names=("SM",))

        def controller_for(role: str):
            if role == "prefill":
                return PrefillPhaseController(hw) if greenllm \
                    else MaxFreqController(hw)
            if not greenllm:
                return MaxFreqController(hw)
            table = dataclasses.replace(self._table,
                                        freq_for=self._table.freq_for.copy())
            return DualLoopController(
                hw, table, DecodeControllerConfig(tbt_slo=self.slo.tbt_target))

        self.replicas: List[Replica] = []

        def add(role: str, i: int, classes: Tuple[str, ...] = ()):
            idx = len(self.replicas)
            eng = ServingEngine(
                cfg, params=params, ecfg=self.ecfg, hw=hw, seed=seed + idx,
                plant_cfg=pcfg,
                plant=PlantModel(cfg=pcfg, hw=hw, n_chips=1,
                                 seed=seed + 100 + idx),
                controller=controller_for(role), name=f"{role}{i}")
            self.replicas.append(Replica(f"{role}{i}", role, eng, classes))

        n_cls = self.dispatcher.num_classes
        per_cls = max(1, n_prefill // n_cls)
        for i in range(n_prefill):
            # contiguous class partition like sim.engine (replica 0.. serve
            # class 0, ...); with fewer replicas than classes, serve all
            classes = () if n_prefill < n_cls else \
                (self.dispatcher.class_names[min(i // per_cls, n_cls - 1)],)
            add("prefill", i, classes)
        for i in range(n_decode):
            add("decode", i)
        for i in range(n_colocated):
            add("colocated", i)

        self.requests: List[Request] = []
        self._future: List[Tuple[float, int, Request, object]] = []
        self._seq = 0
        self._stalled_rounds = 0
        self._events: List = []      # cluster-level events (future cancels)
        # fault tolerance: the (optional) injection plan, the kill log
        # (name, killed_at, energy_j_at_kill — asserted frozen by tests),
        # and the failed-import retry counter
        self.faults = faults
        self.kills: List[Tuple[str, float, float]] = []
        self.import_retries = 0
        # observability: optional sinks fanned out to every replica engine;
        # cluster-level events (faults, handoff retries, prefill DVFS) are
        # emitted here because the engines cannot see them
        self.metrics = None
        self.tracer = None
        self.ledger = None
        self._m_faults = None
        if metrics is not None or tracer is not None or ledger is not None:
            self.install_observability(metrics, tracer, ledger)

    @property
    def events_on(self) -> bool:
        """Event buffering switch (Backend observability surface): setting
        it False tells every replica engine to skip buffering too — the
        ``serving.api.Server`` clears it unless an ``on_event`` callback is
        installed."""
        return all(r.engine.events_on for r in self.replicas)

    @events_on.setter
    def events_on(self, value: bool) -> None:
        for r in self.replicas:
            r.engine.events_on = bool(value)

    def install_observability(self, metrics=None, tracer=None,
                              ledger=None) -> None:
        """Install metrics/trace/attribution sinks on the cluster and every
        replica engine (Backend observability surface — ``serving.api.
        Server`` calls this when built with sinks).  ``None`` leaves a sink
        uninstalled; with none installed every emission site reduces to
        one ``is None`` check (the ``events_on`` zero-overhead pattern).
        A single ``EnergyLedger`` is shared by every replica — that is what
        makes handoff carry a no-op and per-request attribution cluster-
        wide by construction."""
        self.metrics = metrics
        self.tracer = tracer
        if ledger is not None:
            self.ledger = ledger
        if metrics is not None:
            self._m_faults = metrics.counter(
                "greenllm_faults_total",
                "Fault-tolerance events: replica kills, handoff retries "
                "(injected or capacity), page-pressure on/off edges.",
                ("replica", "kind"))
        for r in self.replicas:
            r.engine.install_observability(metrics, tracer, ledger)

    # -- intake ----------------------------------------------------------------
    def submit(self, req: Request,
               prompt_tokens: Optional[np.ndarray] = None) -> None:
        """Queue a request for dispatch at its arrival time."""
        req.cls = self.dispatcher.class_names[
            self.dispatcher.classify(req.prompt_len)]
        heapq.heappush(self._future, (req.arrival, self._seq, req,
                                      prompt_tokens))
        self._seq += 1
        self.requests.append(req)

    def _inject_arrivals(self, now: float) -> None:
        cands = [r for r in self.replicas if r.alive
                 and r.role in ("prefill", "colocated")]
        if not cands:
            if self._future:
                raise RuntimeError(
                    "no live replica can admit requests (every prefill/"
                    "colocated replica is dead) — nothing can recover the "
                    f"{len(self._future)} queued request(s)")
            return
        while self._future and self._future[0][0] <= now:
            _, _, req, ptoks = heapq.heappop(self._future)
            if req.state.terminal:      # cancelled before arrival
                continue
            r = self.dispatcher.pick_prefill(req, cands, self.optimizer)
            r.engine.submit(req, ptoks)

    @property
    def now(self) -> float:
        """Backend protocol: the cluster's clock reading — the max over
        replica clocks (dead replicas stay frozen at their kill time)."""
        return max((r.vtime for r in self.replicas), default=0.0)

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it lives in the cluster: not yet
        arrived (future heap), queued / mid-prefill / mid-decode on a
        replica, or in flight between replicas (import queue — the exported
        page payload is host data and is simply dropped; the source replica
        already released the chain)."""
        return self._terminate(rid, RequestState.CANCELLED)

    def fail(self, rid: int) -> bool:
        """Give up on a request (``Backend.fail`` — the ``Server.run``
        watchdog's lever): same clean release as ``cancel`` with the FAILED
        terminal state."""
        return self._terminate(rid, RequestState.FAILED)

    def evict(self, rid: int) -> bool:
        """Backend protocol: drop a *terminal* request's bookkeeping — the
        cluster-level request row plus every replica's per-request state
        (request row, TBT records).  Returns False (and removes nothing)
        while the request is still live; ``serving.api.Server`` calls this
        to bound memory on long-lived servers."""
        req = next((q for q in self.requests if q.rid == rid), None)
        if req is not None and not req.state.terminal:
            return False
        found = False
        for r in self.replicas:
            found = r.engine.evict(rid) or found
        if req is not None:
            self.requests.remove(req)
            found = True
        return found

    def _terminate(self, rid: int, state: RequestState) -> bool:
        for t, seq, req, ptoks in self._future:
            if req.rid == rid and not req.state.terminal:
                req.state = state
                self._emit(StateEvent(rid, self.now, state))
                return True      # lazily skipped at injection
        for r in self.replicas:
            if r.engine._terminate(rid, state):
                return True
            for pi in list(r.import_q):
                if pi.ho.req.rid == rid:
                    r.import_q.remove(pi)
                    pi.ho.req.state = state
                    self._emit(StateEvent(rid, r.vtime, state))
                    return True
        return False

    def _emit(self, ev) -> None:
        if self.events_on:
            self._events.append(ev)

    def drain_events(self) -> List:
        """Backend protocol: merge every replica's buffered stream events
        (plus cluster-level cancellations) in event-time order."""
        ev = self._events
        self._events = []
        for r in self.replicas:
            ev.extend(r.engine.drain_events())
        ev.sort(key=lambda e: e.time)
        return ev

    # -- per-role stepping ------------------------------------------------------
    def _retune_prefill(self, r: Replica) -> None:
        """Per-phase DVFS: solve Eq. 14 over this replica's queue with the
        deadline set by the oldest queued request's TTFT budget."""
        e = r.engine
        jobs = list(e.pending) + [cs.req for cs in e.prefilling.values()]
        if not jobs or self.optimizer is None:
            return
        lengths = r.queued_lengths()
        oldest = min(q.arrival for q in jobs)
        slo_ttft = min(self.slo.ttft_target(q.cls or "SM") for q in jobs)
        D = deadline_from_queue(lengths, slo_ttft,
                                max(e.vtime - oldest, 0.0))
        D = max(DEADLINE_SAFETY * D - FIRST_TOKEN_RESERVE, 1e-3)
        f, info = self.optimizer.choose_frequency(lengths, D)
        prev = e.controller.freq
        e.controller.freq = f
        e.controller.history.append((e.vtime, f, 0.0))
        if self.tracer is not None and f != prev:
            self.tracer.decision(
                e.vtime, r.name, "prefill", f, info["reason"],
                n_jobs=info["n_jobs"], D=info["D"], busy=info["busy"])

    def _migrate(self, src: Replica, ho: StreamHandoff) -> None:
        dec = [r for r in self.replicas if r.alive and r.role == "decode"]
        assert dec, "no live decode replica (role rebalancing should have " \
                    "converted the prefill replicas to colocated)"
        dst = self.dispatcher.pick_decode(dec)
        dst.import_q.append(_PendingImport(ho))
        src.exported += 1

    def _drain_imports(self, r: Replica) -> bool:
        """Adopt queued handoffs whose retry time has passed on this
        replica's clock.  A refused import — capacity (all-or-nothing slot/
        page allocation) or an injected transient failure — stays queued
        and retries with capped exponential backoff (``HANDOFF_RETRY_BASE``
        doubling to ``HANDOFF_RETRY_CAP``); the stream is never dropped."""
        moved, rest = False, []
        for pi in r.import_q:
            ho = pi.ho
            if ho.req.state.terminal:     # cancelled/failed while in flight
                continue
            if pi.next_try > r.vtime + 1e-12:
                rest.append(pi)
                continue
            injected = self.faults is not None and \
                self.faults.fail_import(r.name, ho.req.rid, r.vtime)
            if not injected and r.engine.import_stream(ho):
                r.imported += 1
                moved = True
            else:
                pi.attempts += 1
                self.import_retries += 1
                pi.next_try = r.vtime + min(
                    HANDOFF_RETRY_BASE * (2.0 ** (pi.attempts - 1)),
                    HANDOFF_RETRY_CAP)
                rest.append(pi)
                if self.tracer is not None:
                    self.tracer.instant(
                        "handoff_retry", ho.req.rid, r.vtime,
                        replica=r.name, attempts=pi.attempts,
                        injected=injected)
                if self._m_faults is not None:
                    self._m_faults.labels(
                        replica=r.name,
                        kind="fault_import" if injected
                        else "handoff_retry").inc()
        r.import_q = rest
        return moved

    def _admit_arrived(self, r: Replica) -> None:
        """Admit only requests that have *arrived* on this replica's clock.

        An idle replica first jumps (billing idle) to the earliest pending
        arrival; requests still in the future are held out of ``_admit`` so
        a batch of injected arrivals can never be prefilled before its
        arrival time (which would yield negative TTFT and bill work early).
        Held requests re-enter on a later step once the clock catches up.
        """
        e = r.engine
        if e.pending and not e.prefilling and not e.active:
            r.advance_to(min(max(q.arrival, q.not_before)
                             for q in e.pending))
        held = [q for q in e.pending
                if max(q.arrival, q.not_before) > e.vtime + 1e-12]
        if held:
            e.pending = [q for q in e.pending
                         if max(q.arrival, q.not_before) <= e.vtime + 1e-12]
        e._admit()
        if held:
            e.pending.extend(held)    # injection order == arrival order

    def _step_prefill(self, r: Replica) -> None:
        e = r.engine
        self._retune_prefill(r)
        self._admit_arrived(r)
        e._advance_chunks()
        for slot in list(e.active):   # completed prefills migrate eagerly
            self._migrate(r, e.export_stream(slot))

    def _step_decode(self, r: Replica) -> None:
        e = r.engine
        if not e.active and not e.prefilling and not e.pending \
                and r.import_q:
            # nothing but parked imports: jump (billing idle) to the
            # earliest adoptable instant — export time or backoff expiry
            r.advance_to(min(max(pi.ho.export_time, pi.next_try)
                             for pi in r.import_q))
        e._evict_lapsed()       # opt-in: lapsed decoders free slots first
        self._drain_imports(r)
        e._admit()              # re-admits locally-preempted streams only
        e._advance_chunks()     # (recompute-on-resume; no raw prompts here)
        if e.active:
            e._decode_block(max(1, e._horizon()))

    def _step_colocated(self, r: Replica) -> None:
        e = r.engine
        e._evict_lapsed()       # opt-in: lapsed decoders free slots first
        self._admit_arrived(r)
        e._advance_chunks()
        if e.active:
            e._decode_block(max(1, e._horizon()))

    # -- fault tolerance --------------------------------------------------------
    def _replica(self, name: str) -> Optional[Replica]:
        return next((r for r in self.replicas if r.name == name), None)

    def kill_replica(self, name: str) -> bool:
        """Crash ``name``: freeze its clock and energy at the kill and
        requeue every stream it held — queued, mid-chunked-prefill,
        mid-decode, or parked in its import queue — at the dispatcher for
        recompute-from-prompt on a survivor.

        Recovery is token-exact for seeded sampled streams: the request
        keeps its emitted ``tokens`` and pinned ``rng_lane``, so the
        survivor replays ``prompt + tokens[:-1]`` through chunked prefill
        (the engine's preemption-resume path) and continues drawing at
        ``fold_in(lane, position)`` — bit-identical to a run that never
        crashed.  The ``not_before`` gate stops a lagging survivor from
        recomputing the work "before" the failure happened; first-token
        timestamps of already-started streams are preserved (recompute is
        not a new TTFT).  Returns False if the replica is unknown or
        already dead."""
        r = self._replica(name)
        if r is None or not r.alive:
            return False
        e = r.engine
        r.alive = False
        r.killed_at = e.vtime
        self.kills.append((r.name, r.killed_at, e.energy_j + r.idle_j))
        victims = ([(q, r.killed_at) for q in e.pending]
                   + [(cs.req, r.killed_at) for cs in e.prefilling.values()]
                   + [(st.req, r.killed_at) for st in e.active.values()]
                   # a handoff parked here may have been exported on a clock
                   # ahead of ours: its recompute may not predate the export
                   + [(pi.ho.req, max(r.killed_at, pi.ho.export_time))
                      for pi in r.import_q])
        if self.tracer is not None:
            self.tracer.instant(
                "replica_kill", -1, r.killed_at, replica=r.name,
                victims=sum(1 for q, _ in victims
                            if not q.state.terminal),
                energy_j=e.energy_j + r.idle_j)
        if self._m_faults is not None:
            self._m_faults.labels(replica=r.name, kind="kill").inc()
        e.pending.clear()
        e.prefilling.clear()
        e.active.clear()
        r.import_q = []
        for req, t in victims:
            if req.state.terminal:
                continue
            self._requeue(req, t)
        self._rebalance_roles()
        return True

    def _requeue(self, req: Request, t: float) -> None:
        """Push a recovered request back through the dispatcher, gated to
        start no earlier than ``t`` on any survivor's clock."""
        req.not_before = max(req.not_before, t)
        req.state = RequestState.QUEUED
        self._emit(StateEvent(req.rid, t, RequestState.QUEUED))
        heapq.heappush(self._future, (max(req.arrival, req.not_before),
                                      self._seq, req, req.prompt))
        self._seq += 1

    def _rebalance_roles(self) -> None:
        """Graceful degradation: if a kill leaves one phase with no live
        replica, the surviving other-phase replicas become colocated (they
        can run both phases, just without the per-phase specialization) —
        the cluster degrades instead of deadlocking on a missing phase."""
        live = [r for r in self.replicas if r.alive]
        if not live:
            return
        has_intake = any(r.role in ("prefill", "colocated") for r in live)
        has_decode = any(r.role in ("decode", "colocated") for r in live)
        if not has_decode:
            for r in live:
                if r.role == "prefill":
                    r.role = "colocated"
        if not has_intake:
            for r in live:
                if r.role == "decode":
                    r.role = "colocated"

    def _apply_faults(self, now: float) -> None:
        if self.faults is None:
            return
        for ev in self.faults.due_kills(now):
            self.kill_replica(ev.replica)
        for ev, edge in self.faults.pressure_changes(now):
            r = self._replica(ev.replica)
            if r is None or not r.alive or r.engine.pager is None:
                continue
            if edge == "on":
                r.engine.pager.reserve(ev.pages)
            else:
                r.engine.pager.release_reserved()
            if self.tracer is not None:
                name, attrs = ev.describe()
                self.tracer.instant(name, -1, now, replica=ev.replica,
                                    edge=edge, **attrs)
            if self._m_faults is not None:
                self._m_faults.labels(replica=ev.replica,
                                      kind=f"pressure_{edge}").inc()

    def has_work(self) -> bool:
        """Backend protocol: future arrivals or any live replica with
        work (a dead replica's leftovers were requeued at the kill)."""
        return bool(self._future) or any(r.has_work() for r in self.replicas
                                         if r.alive)

    # -- main loop --------------------------------------------------------------
    def step(self) -> bool:
        """Advance the laggard live replica by one unit of work (an
        admission round, a chunk round, or one decode block), applying any
        fault-plan events due at the cluster clock first.  Returns False
        when the cluster is drained."""
        workers = [r for r in self.replicas if r.alive and r.has_work()]
        now = min((r.vtime for r in workers), default=None)
        if now is None:
            if not self._future:
                return False
            now = self._future[0][0]
        self._apply_faults(now)
        self._inject_arrivals(now)
        workers = [r for r in self.replicas if r.alive and r.has_work()]
        if not workers:
            return bool(self._future)
        r = min(workers, key=lambda x: x.vtime)
        marker = self._progress_marker()
        if r.role == "prefill":
            self._step_prefill(r)
        elif r.role == "decode":
            self._step_decode(r)
        else:
            self._step_colocated(r)
        if self._progress_marker() == marker:
            self._stalled_rounds += 1
            if self._stalled_rounds > 4 * len(self.replicas) + 8:
                raise RuntimeError(
                    f"cluster stalled: replica {r.name} makes no progress "
                    f"(pending={len(r.engine.pending)} "
                    f"prefilling={len(r.engine.prefilling)} "
                    f"imports={len(r.import_q)})")
        else:
            self._stalled_rounds = 0
        return True

    def _progress_marker(self):
        done = sum(1 for q in self.requests if q.finish >= 0)
        return (done, sum(r.vtime for r in self.replicas),
                sum(r.imported + r.exported for r in self.replicas),
                sum(len(r.engine.pending) + len(r.engine.prefilling)
                    + len(r.engine.active) for r in self.replicas))

    # -- metrics ----------------------------------------------------------------
    def report(self) -> ServingReport:
        """Backend protocol: cluster roll-up as the shared typed report —
        per-replica energy split (active by phase + idle up to the shared
        makespan) and request-level SLO metrics scored by the same
        definition as the simulator and the single engine.  Requests carry
        cluster-wide state; TBT records live on whichever replica decoded
        the stream."""
        makespan = max((r.vtime for r in self.replicas), default=0.0)
        rows: List[ReplicaReport] = []
        for r in self.replicas:
            e = r.engine
            # a live replica is billed idle power up to the shared makespan;
            # a dead one stops accumulating *anything* at the kill — that is
            # what keeps total energy comparable between a kill trace and a
            # healthy run (recompute is billed where it runs)
            extra = ((makespan - r.vtime) * e.plant.idle_power
                     if r.alive else 0.0)
            idle = r.idle_j + extra
            if self.ledger is not None:
                # report-time idle goes into the ledger's idempotent top-up
                # slot (report() may run several times) with the identical
                # float, keeping the idle mirror bitwise equal to this row
                self.ledger.set_idle_topup(r.name, extra)
            rows.append(ReplicaReport(
                name=r.name, role=r.role, vtime_s=r.vtime,
                prefill_energy_j=e.prefill_energy_j,
                decode_energy_j=e.decode_energy_j,
                idle_energy_j=idle,
                energy_j=e.energy_j + idle,
                prefill_tokens=e.prefill_tokens,
                decode_tokens=e.decode_tokens,
                exported=r.exported, imported=r.imported,
                preempted=e._preempted,
                page_occupancy_peak=e.page_occupancy_peak(),
                freq_mhz=e.controller.freq,
                alive=r.alive, killed_at=r.killed_at,
                energy_saved_j=self.ledger.replica_saved_j(r.name)
                if self.ledger is not None else 0.0))
        tbt: Dict[int, List[float]] = {}
        for r in self.replicas:
            for rid, v in r.engine._tbt.items():
                tbt.setdefault(rid, []).extend(v)
        led = {}
        if self.ledger is not None:
            led = dict(energy_by_rid=self.ledger.energy_by_rid(),
                       saved_by_rid=self.ledger.saved_by_rid(),
                       energy_saved_j=self.ledger.saved_total_j())
        return build_report(
            backend="cluster", requests=self.requests, tbt_records=tbt,
            slo=self.slo, class_names=self.dispatcher.class_names,
            prefill_energy_j=sum(w.prefill_energy_j for w in rows),
            decode_energy_j=sum(w.decode_energy_j for w in rows),
            idle_energy_j=sum(w.idle_energy_j for w in rows),
            prefill_tokens=sum(w.prefill_tokens for w in rows),
            decode_tokens=sum(w.decode_tokens for w in rows),
            duration_s=makespan,
            preempted=sum(w.preempted for w in rows),
            migrated=sum(w.imported for w in rows),
            page_occupancy_peak=max([w.page_occupancy_peak for w in rows]
                                    or [0.0]),
            replicas=tuple(rows), **led)

    def stats(self) -> Dict:
        """Legacy dict view, kept for one release: derived entirely from
        ``report()`` so there is a single metrics definition."""
        rep = self.report()
        return {
            "replicas": [dataclasses.asdict(w) for w in rep.replicas],
            "mesh": self.ecfg.mesh,
            "completed": rep.completed,
            "failed": rep.failed,
            "shed": rep.shed,
            "n_requests": rep.n_requests,
            "makespan_s": rep.duration_s,
            "handoffs": rep.migrated,
            "preempted": rep.preempted,
            "ttft_pass": rep.ttft_pass,
            "tbt_pass": rep.tbt_pass,
            "p90_ttft_s": dict(rep.p90_ttft_s),
            "p95_tbt_ms": rep.p95_tbt_s * 1e3,
            "p99_tbt_ms": rep.p99_tbt_s * 1e3,
            "prefill_energy_j": rep.prefill_energy_j,
            "decode_energy_j": rep.decode_energy_j,
            "idle_energy_j": rep.idle_energy_j,
            "energy_j": rep.total_energy_j,
            "prefill_tokens": rep.prefill_tokens,
            "decode_tokens": rep.decode_tokens,
        }
