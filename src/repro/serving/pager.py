"""Paged KV-cache subsystem: block allocator + device-side page table.

vLLM-style paging for the slot-native serving engine: every full-length
attention buffer (the ``max_len`` K/V rows that gate concurrent-stream
capacity) is replaced by a shared pool of fixed-size pages, and each stream
holds a *chain* of pages covering exactly its live context.  Capacity is then
bounded by total tokens in flight, not ``max_batch x max_len``, which is the
phase-aware capacity lever GreenLLM's decode controller needs (decode is
memory-bound; energy/token falls with batch size at fixed frequency).

Split of responsibilities:

* **Host-side policy** (this module): a free-list allocator with per-stream
  page chains — alloc on admit, incremental grow at decode-block boundaries,
  free at retire.  All decisions happen at admission/block granularity, so the
  engine's no-per-token-host-sync invariant is preserved.
* **Device-side mechanism**: a ``(max_streams, max_pages_per_stream)`` int32
  page table mapping (slot, logical page) -> physical page id.  The jitted
  decode/prefill kernels receive a ctx-bucketed slice of this table and
  gather/scatter K/V by physical page; the table is re-uploaded only when the
  host allocator mutates it (admit / grow / retire — never per token).

Page 0 is a reserved scratch page: freed streams' table rows point at it, so
the (held) writes of inactive batch rows inside a decode block land in scratch
instead of corrupting pages that may have been reallocated to other streams.
Reads from scratch are position-masked exactly like unwritten dense slots.

**Refcounted sharing** (serving.prefix_cache): a physical page may be held by
several stream chains at once (a shared prompt prefix) and/or retained by the
prefix cache itself.  ``ref[p]`` counts the holders — one per chain containing
``p`` plus one if the cache retains it — and a page returns to the free list
only when the count reaches zero.  ``share_chain`` seeds a fresh chain from
existing pages (incref, no data movement), ``cow_page`` gives one chain a
private copy of a shared page before its first write (copy-on-write on
divergence; the *contents* are copied by the caller on device), and
``retain``/``release`` are the cache's grip.  All existing call sites see the
old exclusive-ownership behavior unchanged: without sharing every ref is 1
and ``free_chain`` frees eagerly, exactly as before.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

SCRATCH_PAGE = 0


class PageAllocator:
    """Free-list page allocator with per-stream chains and a host-shadowed
    device page table.

    Invariants (property-tested in tests/test_paging.py and, under sharing,
    tests/test_prefix_cache.py):
    * a physical page is either on the free list (ref 0) or held (ref ==
      #chains containing it + 1 if cache-retained); double frees raise;
    * ``pages_used + pages_free == num_pages - 1`` (scratch excluded), where
      ``pages_used`` counts *distinct* held pages — a page shared by N
      streams is one page, not N;
    * chains grow monotonically between ``free_chain`` calls and drop every
      reference at retire (pages with no other holder return to the pool);
    * table rows of unallocated logical pages (and of freed streams) point at
      ``SCRATCH_PAGE``.
    """

    def __init__(self, num_pages: int, page_size: int, max_streams: int,
                 max_pages_per_stream: int):
        assert num_pages >= 2, "need at least scratch + one usable page"
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_pages_per_stream = max_pages_per_stream
        # LIFO free list: recently-freed pages are reused first (locality)
        self._free: List[int] = list(range(num_pages - 1, SCRATCH_PAGE, -1))
        self._free_set = set(self._free)
        self.chains: Dict[int, List[int]] = {}
        self._reserved: List[int] = []   # withheld by reserve() (fault inj.)
        # holder counts: chains containing the page + 1 if cache-retained;
        # 0 <=> on the free list (scratch excluded from both)
        self.ref = np.zeros(num_pages, np.int32)
        self._retained = set()           # pages gripped by the prefix cache
        self.peak_used = 0               # run peak, monotone (telemetry)
        self.table = np.full((max_streams, max_pages_per_stream),
                             SCRATCH_PAGE, np.int32)
        self._dev = None          # cached device copy, refreshed when dirty
        self._dirty = True
        # optional jax.sharding.Sharding applied at upload (mesh serving
        # shards rows along the data axis); None -> default placement
        self.device_sharding = None

    # -- capacity queries -----------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size)

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_used(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self._free)

    # -- pressure injection (serving.faults) ----------------------------------
    def reserve(self, n_pages: int) -> int:
        """Withhold up to ``n_pages`` free pages from the pool (a simulated
        external pressure spike: co-tenant allocation, fragmentation burst).
        Returns the number actually withheld — never more than the free
        list holds, so live chains are untouched.  Reserved pages count as
        used (``pages_used`` is derived from the free list), preserving the
        ``pages_used + pages_free == num_pages - 1`` invariant; the engine
        responds with its normal pressure ladder (shrink blocks, preempt
        youngest, gate admission)."""
        take = min(max(n_pages, 0), len(self._free))
        for _ in range(take):
            page = self._free.pop()
            self._free_set.discard(page)
            self._reserved.append(page)
        if take:
            self.peak_used = max(self.peak_used, self.pages_used)
        return take

    def release_reserved(self) -> int:
        """Return every reserved page to the free list (pressure spike
        over).  Returns the number released."""
        n = len(self._reserved)
        while self._reserved:
            page = self._reserved.pop()
            self._free.append(page)
            self._free_set.add(page)
        return n

    @property
    def pages_reserved(self) -> int:
        return len(self._reserved)

    # -- alloc / grow / free --------------------------------------------------
    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s chain to cover ``n_tokens``; all-or-nothing.

        Returns False (allocating nothing) if the free list can't cover the
        growth — the caller shrinks its decode block or preempts a stream.
        """
        chain = self.chains.setdefault(slot, [])
        need = self.pages_for(n_tokens) - len(chain)
        if need <= 0:
            return True
        if need > len(self._free):
            return False
        if len(chain) + need > self.max_pages_per_stream:
            raise ValueError(
                f"stream {slot} needs {len(chain) + need} pages "
                f"> max_pages_per_stream={self.max_pages_per_stream}")
        for _ in range(need):
            page = self._free.pop()
            self._free_set.discard(page)
            self.ref[page] = 1
            self.table[slot, len(chain)] = page
            chain.append(page)
        self.peak_used = max(self.peak_used, self.pages_used)
        self._dirty = True
        return True

    def _drop_ref(self, page: int, who: str) -> None:
        """Release one holder's reference; the page returns to the free list
        only when nobody — chain or cache — holds it anymore."""
        if page in self._free_set or page == SCRATCH_PAGE \
                or self.ref[page] <= 0:
            raise ValueError(f"double free of page {page} ({who})")
        self.ref[page] -= 1
        if self.ref[page] == 0:
            self._free.append(page)
            self._free_set.add(page)

    def free_chain(self, slot: int) -> int:
        """Drop ``slot``'s reference on every page of its chain (pages with
        no other holder return to the free list) and point the table row
        back at scratch.  Returns the chain length released."""
        chain = self.chains.pop(slot, [])
        for page in chain:
            self._drop_ref(page, f"slot {slot}")
        if chain:
            self.table[slot, :] = SCRATCH_PAGE
            self._dirty = True
        return len(chain)

    # -- prefix sharing (serving.prefix_cache) --------------------------------
    def share_chain(self, slot: int, pages: List[int]) -> None:
        """Seed ``slot``'s (empty) chain with existing live pages — no data
        moves, each page just gains a reference.  This is how a prefix-cache
        hit adopts the cached pages of a shared prompt."""
        if self.chains.get(slot):
            raise ValueError(f"slot {slot} already holds a chain; "
                             "free it before sharing into it")
        if len(pages) > self.max_pages_per_stream:
            raise ValueError(
                f"shared prefix of {len(pages)} pages "
                f"> max_pages_per_stream={self.max_pages_per_stream}")
        chain = []
        for i, page in enumerate(pages):
            if page == SCRATCH_PAGE or page in self._free_set \
                    or self.ref[page] <= 0:
                raise ValueError(f"cannot share dead page {page}")
            self.ref[page] += 1
            self.table[slot, i] = page
            chain.append(page)
        self.chains[slot] = chain
        if chain:
            self._dirty = True

    def cow_page(self, slot: int, logical: int) -> Optional[int]:
        """Copy-on-write: give ``slot`` a private copy of logical page
        ``logical`` before its first write into it.  Exclusively-held pages
        are already private (returned as-is); shared ones are swapped for a
        fresh page (or None — changing nothing — if the pool is dry).  The
        caller must copy the page *contents* on device (e.g.
        ``kvcache.paged_page_copy``) when the returned id differs."""
        chain = self.chains[slot]
        page = chain[logical]
        if self.ref[page] == 1:
            return page
        if not self._free:
            return None
        new = self._free.pop()
        self._free_set.discard(new)
        self.ref[new] = 1
        self.ref[page] -= 1       # >= 1 left: another chain or the cache
        chain[logical] = new
        self.table[slot, logical] = new
        self.peak_used = max(self.peak_used, self.pages_used)
        self._dirty = True
        return new

    def retain(self, page: int) -> None:
        """The prefix cache grips ``page``: it survives ``free_chain`` until
        ``release``d, keeping its contents addressable for future hits."""
        if page == SCRATCH_PAGE or page in self._free_set \
                or self.ref[page] <= 0:
            raise ValueError(f"cannot retain dead page {page}")
        if page in self._retained:
            raise ValueError(f"page {page} already retained")
        self.ref[page] += 1
        self._retained.add(page)

    def release(self, page: int) -> None:
        """Drop the cache's grip on ``page`` (eviction); the page frees now
        if no chain still holds it, or when the last chain retires."""
        if page not in self._retained:
            raise ValueError(f"page {page} is not retained")
        self._retained.discard(page)
        self._drop_ref(page, "cache")

    def stream_refs(self, page: int) -> int:
        """How many stream chains hold ``page`` (cache grip excluded)."""
        return int(self.ref[page]) - (1 if page in self._retained else 0)

    @property
    def pages_retained(self) -> int:
        return len(self._retained)

    # -- migration (replica-to-replica paged-KV handoff) ----------------------
    def export_chain(self, slot: int) -> List[int]:
        """Release ``slot``'s chain for migration: returns the physical page
        ids (in logical order) and hands them back to the free list, pointing
        the table row at scratch.

        The caller must have copied the pages' *contents* out (e.g. via
        ``kvcache.paged_chain_extract``) before calling — after this returns,
        the pages may be reallocated to other streams at the next ``ensure``.
        """
        chain = list(self.chains.get(slot, []))
        self.free_chain(slot)
        return chain

    def adopt_chain(self, slot: int, n_pages: int) -> Optional[List[int]]:
        """Allocate a fresh chain of exactly ``n_pages`` for an imported
        stream and return the physical ids (scatter targets for
        ``kvcache.paged_chain_insert``), or None — allocating nothing — if the
        free list cannot cover it.  ``slot`` must not already hold a chain:
        adoption is the first act of an imported stream's life on this pool.
        """
        if self.chains.get(slot):
            raise ValueError(f"slot {slot} already holds a chain; "
                             "free it before adopting")
        if not self.ensure(slot, n_pages * self.page_size):
            return None
        return list(self.chains[slot])

    # -- device table ---------------------------------------------------------
    def table_device(self):
        """jnp copy of the table; re-uploaded only after host mutations."""
        if self._dirty or self._dev is None:
            import jax
            import jax.numpy as jnp
            self._dev = jnp.asarray(self.table)
            if self.device_sharding is not None:
                self._dev = jax.device_put(self._dev, self.device_sharding)
            self._dirty = False
        return self._dev

    # -- telemetry ------------------------------------------------------------
    def occupancy(self, live_tokens: Optional[Dict[int, int]] = None) -> Dict:
        """Pool pressure for ``stats()``/telemetry: later energy PRs feed
        ``occupancy`` to the controller as a memory-pressure input.

        Reserved, shared, and cache-retained pages are counted *distinctly*:
        ``pages_used`` is derived from the free list, so a page shared by N
        streams contributes one page, and ``pages_shared`` /
        ``pages_reserved`` / ``pages_cached`` break the total down without
        double-counting.  ``occupancy_live`` excludes pages only the prefix
        cache holds (evictable on demand) — the decode controller's
        ``occ_high`` bias reads this so a warm cache is not mistaken for
        pool pressure.

        ``fragmentation`` is internal (last-page slack): 1 - live tokens /
        token slots held, over *distinct* held pages — a shared page's
        utilization is the max coverage over its sharers.  There is no
        external fragmentation — pages are uniform — so this is the only
        capacity lost to the page granularity.
        """
        usable = self.num_pages - 1
        used = self.pages_used
        counts: Dict[int, int] = {}
        for chain in self.chains.values():
            for p in chain:
                counts[p] = counts.get(p, 0) + 1
        shared = sum(1 for n in counts.values() if n > 1)
        # pages only the cache holds (no chain): freeable by eviction
        evictable = sum(1 for p in self._retained if p not in counts)
        frag = 0.0
        if live_tokens is not None and used:
            ps = self.page_size
            cover: Dict[int, int] = {}
            for s, live in live_tokens.items():
                for i, p in enumerate(self.chains.get(s, [])):
                    c = min(max(live - i * ps, 0), ps)
                    cover[p] = max(cover.get(p, 0), c)
            if cover:
                frag = 1.0 - sum(cover.values()) / (len(cover) * ps)
        return {
            "pages_used": used,
            "pages_total": usable,
            "pages_shared": shared,
            "pages_reserved": len(self._reserved),
            "pages_cached": len(self._retained),
            "pages_evictable": evictable,
            "occupancy": used / usable if usable else 0.0,
            "occupancy_live": (used - evictable) / usable if usable else 0.0,
            "peak_occupancy": self.peak_used / usable if usable else 0.0,
            "fragmentation": frag,
        }
