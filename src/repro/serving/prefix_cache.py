"""Content-addressed prefix cache over the paged KV pool (ROADMAP item 3).

Chat and agent traffic re-prefills the same system prompts and RAG templates
thousands of times, and prefill energy scales directly with processed prompt
tokens (Maliakel et al., PAPERS.md) — so the complementary lever to GreenLLM's
frequency scaling is simply *not recomputing* shared prefixes.  This module
is the vLLM-style realization over ``serving.pager``:

* **Content addressing** — the unit of sharing is one *page-aligned* chunk of
  prompt token ids.  Entry ``i`` of a prompt is keyed by a digest chain
  ``d_i = H(d_{i-1} || tokens[i*ps:(i+1)*ps])`` (H = blake2b-128), so a page
  is reachable only through its exact ancestry: two prompts share entries for
  precisely their common page-aligned prefix, and a one-token divergence
  changes every digest from that page on.
* **Refcounted pages, zero-copy hits** — an entry's payload is a physical
  page in the existing ``PageAllocator`` pool, gripped via
  ``PageAllocator.retain`` so it survives the producing stream's retirement.
  A hit seeds the new stream's chain with the cached pages through
  ``share_chain`` (incref, no data movement) and chunked prefill starts at
  the matched position; the K/V *bits* are the original stream's, which is
  exactly what makes hit == miss token-identical at f32 (the PR 2 invariant).
* **Copy-on-write on divergence** — a stream that must write into a shared
  page (the fully-covered-prompt case: its first real prefill token rewrites
  the last matched page's final position) gets a private copy first
  (``cow_page`` + a device page copy); everything past the shared prefix
  lands in freshly-allocated private pages, so cached pages are immutable
  once registered.
* **LRU eviction over unreferenced leaves only** — ``reclaim`` (called by the
  engine when the free list runs dry, *before* preempting a live stream)
  evicts least-recently-used entries that no stream chain references and
  that no longer entry extends; a cached prefix can therefore never yank a
  page out from under a live chain, and interior entries never orphan their
  descendants.

Only fully-paged models participate (every attention stage a paged pool:
dense / GQA / kv_quant full-attention layouts).  Hybrid models with ring or
recurrent (SSM / RG-LRU) row state carry per-position state outside the page
pool, so their lookups report misses and their pages are never registered —
correctness by construction, caching win deferred.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from .pager import PageAllocator


class _Entry:
    __slots__ = ("digest", "parent", "page", "children", "stamp")

    def __init__(self, digest: bytes, parent: Optional[bytes], page: int,
                 stamp: int):
        self.digest = digest
        self.parent = parent
        self.page = page
        self.children = 0       # entries extending this one (evict leaves only)
        self.stamp = stamp      # LRU clock at last touch


def _digest(parent: Optional[bytes], page_tokens: np.ndarray) -> bytes:
    h = hashlib.blake2b(digest_size=16)
    if parent is not None:
        h.update(parent)
    h.update(np.ascontiguousarray(page_tokens, np.int32).tobytes())
    return h.digest()


class PrefixCache:
    """Digest-chained map of page-aligned prompt chunks to retained pages.

    ``max_pages`` bounds the number of retained pages (0 = bounded only by
    pool pressure: the engine calls ``reclaim`` when allocation fails).
    Counters (hits / misses / evictions / tokens served from cache) feed the
    ``greenllm_prefix_cache_*`` metrics.
    """

    def __init__(self, pager: PageAllocator, max_pages: int = 0):
        self.pager = pager
        self.max_pages = max_pages
        self.entries: Dict[bytes, _Entry] = {}
        self.hits = 0           # lookups that matched >= 1 page
        self.misses = 0         # lookups that matched nothing
        self.evictions = 0      # entries dropped by reclaim()
        self.hit_tokens = 0     # prompt tokens served from cache (all hits)
        self._clock = 0         # LRU stamp source (monotone, not vtime)

    def __len__(self) -> int:
        return len(self.entries)

    # -- read side -------------------------------------------------------------
    def _walk(self, tokens: np.ndarray) -> List[_Entry]:
        """Longest chain of cached entries covering full pages of
        ``tokens``; stops at the first unknown digest."""
        ps = self.pager.page_size
        out: List[_Entry] = []
        parent: Optional[bytes] = None
        i = 0
        while (i + 1) * ps <= len(tokens):
            d = _digest(parent, tokens[i * ps:(i + 1) * ps])
            e = self.entries.get(d)
            if e is None:
                break
            out.append(e)
            parent = d
            i += 1
        return out

    def probe(self, tokens: np.ndarray) -> int:
        """Matched-prefix length in tokens, counters and LRU untouched —
        the pure query ``busy_time`` accounting and routing use.  Capped at
        ``len(tokens) - 1``: at least one token must be genuinely prefilled
        so the first-token logits exist."""
        if not self.entries or len(tokens) < 2:
            return 0
        n = len(self._walk(np.asarray(tokens, np.int32)))
        return min(n * self.pager.page_size, len(tokens) - 1)

    def lookup(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Admission-time match: returns (cached physical pages, matched
        tokens) and bumps hit/miss counters + LRU stamps.  The token count
        is capped at ``len(tokens) - 1`` (see ``probe``); when the cap bites
        — a page-aligned prompt fully covered by the cache — the *last*
        matched page must be copied-on-write by the caller, because the
        one remaining prefill token rewrites that page's final position."""
        tokens = np.asarray(tokens, np.int32)
        chain = self._walk(tokens) if len(tokens) >= 2 else []
        matched = min(len(chain) * self.pager.page_size, len(tokens) - 1) \
            if chain else 0
        n_pages = -(-matched // self.pager.page_size)
        chain = chain[:n_pages]
        if matched:
            self.hits += 1
            self.hit_tokens += matched
            self._clock += 1
            for e in chain:
                e.stamp = self._clock
        else:
            self.misses += 1
        return [e.page for e in chain], matched

    # -- write side ------------------------------------------------------------
    def register(self, tokens: np.ndarray, chain: List[int],
                 upto: int) -> int:
        """Insert the fully-written pages of a (partial) prompt: page ``i``
        of ``chain`` is registered iff ``(i+1)*ps <= upto`` (both the token
        content *and* the K/V contents of the page are complete).  Existing
        digests are touched, not replaced — first writer wins, so a page is
        retained at most once.  Returns the number of new entries."""
        ps = self.pager.page_size
        tokens = np.asarray(tokens, np.int32)
        limit = min(upto, len(tokens))
        parent: Optional[bytes] = None
        added = 0
        self._clock += 1
        for i in range(limit // ps):
            if i >= len(chain):
                break
            d = _digest(parent, tokens[i * ps:(i + 1) * ps])
            e = self.entries.get(d)
            if e is None:
                if self.max_pages and \
                        self.pager.pages_retained >= self.max_pages and \
                        not self.reclaim(1):
                    break       # at capacity and nothing evictable: stop
                self.pager.retain(chain[i])
                e = _Entry(d, parent, chain[i], self._clock)
                self.entries[d] = e
                if parent is not None:
                    self.entries[parent].children += 1
                added += 1
            else:
                e.stamp = self._clock
            parent = d
        return added

    # -- eviction --------------------------------------------------------------
    def _evictable(self, e: _Entry) -> bool:
        """Leaves of the digest tree that no live stream chain shares:
        eviction may only drop pages whose sole holder is the cache."""
        return e.children == 0 and self.pager.stream_refs(e.page) == 0

    def reclaim(self, n_pages: int) -> int:
        """Evict up to ``n_pages`` LRU evictable entries, freeing their
        pages back to the pool.  Called by the engine when ``ensure`` /
        admission fails before it reaches for preemption — cached prefixes
        are strictly less valuable than live work.  Returns pages freed."""
        freed = 0
        while freed < n_pages:
            victim = None
            for e in self.entries.values():
                if self._evictable(e) and \
                        (victim is None or e.stamp < victim.stamp):
                    victim = e
            if victim is None:
                break
            self._drop(victim)
            freed += 1
        return freed

    def _drop(self, e: _Entry) -> None:
        del self.entries[e.digest]
        if e.parent is not None:
            parent = self.entries.get(e.parent)
            if parent is not None:
                parent.children -= 1
        self.pager.release(e.page)
        self.evictions += 1

    def clear(self) -> int:
        """Release every entry (leaves first).  Returns entries dropped —
        after this the pool owes nothing to the cache, which is what the
        leak tests assert against."""
        dropped = 0
        while self.entries:
            leaves = [e for e in self.entries.values() if e.children == 0]
            assert leaves, "digest tree cycle (impossible by construction)"
            for e in leaves:
                self._drop(e)
                dropped += 1
        return dropped

    # -- telemetry -------------------------------------------------------------
    def shared_pages(self) -> int:
        """Cached pages currently also held by >= 1 live stream chain (the
        ``greenllm_prefix_cache_shared_pages`` gauge)."""
        return sum(1 for e in self.entries.values()
                   if self.pager.stream_refs(e.page) > 0)

    def stats(self) -> Dict:
        total = self.hits + self.misses
        return {
            "entries": len(self.entries),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_tokens": self.hit_tokens,
            "hit_rate": self.hits / total if total else 0.0,
            "shared_pages": self.shared_pages(),
        }
