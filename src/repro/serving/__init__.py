from .engine import ServingEngine, EngineConfig, StreamHandoff
from .pager import PageAllocator, SCRATCH_PAGE
from .prefix_cache import PrefixCache
from .cluster import (ServingCluster, ClusterDispatcher, Replica,
                      PrefillPhaseController)
from .api import Backend, RequestHandle, Server, WatchdogConfig
from .faults import (FaultPlan, HandoffFailure, PagePressureSpike,
                     ReplicaKill)
