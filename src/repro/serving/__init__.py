from .engine import ServingEngine, EngineConfig
from .pager import PageAllocator, SCRATCH_PAGE
