"""Request-lifecycle serving API: the one front door over every data plane.

``Server`` wraps any ``Backend`` — the real-execution ``ServingEngine``, the
disaggregated ``ServingCluster``, or the discrete-event
``sim.ServingSimulator`` — behind a submit → stream → cancel surface:

    server = Server(ServingEngine(cfg, ...))
    h = server.submit(prompt_tokens, SamplingParams(max_tokens=32),
                      arrival=0.25)
    for tok in h.tokens():        # drains at decode-block granularity
        ...
    h.cancel()                    # queued, mid-chunked-prefill or mid-decode
    report = server.run()         # typed ServingReport (core.report)

Design constraints inherited from the engine (ROADMAP invariants):

* **No new per-token host syncs** — handles do not poll the device.  The
  backends append tokens to each ``Request`` (and buffer ``TokenEvent`` /
  ``StateEvent`` records for ``drain_events`` consumers) at their natural
  cadence — the real engines once per decode block, the simulator per
  discrete event — and handles read that list through a cursor.
  ``handle.tokens()`` therefore yields in bursts of block size.
* **One driver loop** — ``Server.run`` / ``Server._pump`` is the only place
  that steps a backend (the legacy ``run_until_drained`` shims are gone).
* **Typed results** — every backend's ``report()`` returns the same
  ``ServingReport``; there are no string-keyed stats dicts to adapt.
* **Graceful failure** — an optional ``WatchdogConfig`` makes ``run`` fail
  (``Backend.fail`` -> ``RequestState.FAILED``) streams that exceed a
  per-request wall budget on the backend's virtual clock, and detect a
  stuck backend (claims work, makes no progress) instead of spinning
  forever — requests are released cleanly, tokens already produced stay
  readable, and the typed report still comes back.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, Iterator, List, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core import Request, RequestState, SamplingParams, ServingReport


@runtime_checkable
class Backend(Protocol):
    """What a data plane must expose to sit behind ``Server``.

    Implemented by ``serving.ServingEngine``, ``serving.ServingCluster``
    and ``sim.ServingSimulator``.  ``step`` advances one unit of work (a
    decode block / an admission round / one discrete event); ``has_work``
    is False exactly when the backend is drained; ``drain_events`` hands
    out buffered stream events (cleared on read); ``cancel`` releases a
    request anywhere short of completion; ``fail`` does the same with the
    FAILED terminal state (the system giving up, not the caller); ``now``
    is the backend's virtual-clock reading (what watchdog budgets compare
    against); ``report`` builds the shared typed report over everything
    served so far.

    Two optional surfaces (every shipped backend has both; ``Server``
    probes with ``hasattr``): ``install_observability(metrics, tracer,
    ledger)`` accepts a ``core.metrics.MetricsRegistry`` /
    ``core.tracing.Tracer`` / ``core.attribution.EnergyLedger`` triple,
    and ``evict(rid)`` drops a *terminal* request's per-request
    bookkeeping (returning False while it is live) so long-lived servers
    can bound memory (``Server(retain_reports=...)``).
    """

    def submit(self, req: Request,
               prompt_tokens: Optional[np.ndarray] = None) -> None: ...

    def has_work(self) -> bool: ...

    def step(self) -> object: ...

    def drain_events(self) -> List: ...

    def cancel(self, rid: int) -> bool: ...

    def fail(self, rid: int) -> bool: ...

    @property
    def now(self) -> float: ...

    def report(self) -> ServingReport: ...


@dataclasses.dataclass(frozen=True)
class WatchdogConfig:
    """``Server.run`` failure policy (off unless passed to ``Server``).

    ``request_budget_s`` is a per-request wall budget on the *backend's
    virtual clock*: a request still non-terminal ``budget`` seconds after
    its arrival is failed cleanly (slot/pages released, FAILED state, the
    report still scores it).  ``stall_rounds`` guards against a stuck
    backend: if the backend claims ``has_work()`` but neither its clock
    nor any stream's token count moves for that many consecutive pump
    rounds, every in-flight request is failed and the run stops instead of
    spinning forever (0 disables the stall guard)."""
    request_budget_s: float = float("inf")
    stall_rounds: int = 0


class RequestHandle:
    """A live view of one submitted request.

    ``tokens()`` streams token ids incrementally (bursts of decode-block
    size — see module docstring); ``result()`` blocks until the request is
    terminal — FINISHED, CANCELLED, FAILED (watchdog / backend gave up) or
    SHED (deadline-aware admission dropped it) — and returns its
    ``Request``; ``cancel()`` releases it mid-queue, mid-chunked-prefill or
    mid-decode.  The discrete-event simulator emits token *counts* only, so
    its handles stream nothing but still resolve ``result()`` / ``state``.
    """

    def __init__(self, server: "Server", req: Request):
        self._server = server
        self.request = req
        self._cursor = 0        # next unread index into request.tokens

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def state(self) -> RequestState:
        return self.request.state

    @property
    def done(self) -> bool:
        return self.request.state.terminal

    def tokens(self) -> Iterator[int]:
        """Yield output token ids as the backend produces them; returns
        when the request finishes or is cancelled (tokens produced before
        a cancel remain readable).

        The handle reads ``request.tokens`` through a cursor — the list the
        backend appends to at block granularity — so streaming adds no
        per-request copy of the output.  The simulator emits token *counts*
        only (``request.tokens`` stays empty), so its handles stream
        nothing but still resolve ``result()`` / ``state``."""
        while True:
            toks = self.request.tokens
            while self._cursor < len(toks):
                tok = toks[self._cursor]
                self._cursor += 1
                yield tok
            if self.done:
                return
            if not self._server._pump():
                return          # backend drained without finishing us

    def result(self) -> Request:
        """Run the backend until this request is terminal; returns the
        ``Request`` (token ids in ``.tokens``, timestamps/state on it)."""
        for _ in self.tokens():
            pass
        return self.request

    def cancel(self) -> bool:
        """Release the request wherever it lives (slot freed, page chain
        released, recurrent state frozen).  Tokens already produced stay
        buffered and readable.  False if it was already terminal."""
        if self.done:
            return False
        return self._server.backend.cancel(self.rid)


class Server:
    """The serving front door: submit → stream → cancel over any backend.

    ``on_event`` (optional) is the push-side observability hook: every
    buffered ``TokenEvent`` / ``StateEvent`` the backend produces is handed
    to the callback, in order, each time the driver loop drains — i.e. at
    the backend's natural cadence (decode blocks for the real engines),
    never per token.  When no callback is installed the Server tells the
    backend to skip event buffering entirely (``backend.events_on``), so
    nobody pays for an observability surface nobody reads; handles keep
    streaming through their request token lists either way.

    ``metrics`` / ``tracer`` (optional) are the pull-side observability
    sinks — a ``core.metrics.MetricsRegistry`` and ``core.tracing.Tracer``
    installed into the backend at construction: the backend publishes
    gauges/counters/histograms and request-lifecycle spans at its block
    cadence, and the registry's ``record_snapshot`` timeline makes any
    metric queryable at any virtual-clock instant.  ``retain_reports``
    bounds a long-lived server's memory: only the N most recently finished
    requests keep handles and backend bookkeeping (older terminal requests
    are evicted via ``Backend.evict`` and drop out of ``report()``).
    """

    def __init__(self, backend: Backend, on_event=None,
                 watchdog: Optional[WatchdogConfig] = None,
                 metrics=None, tracer=None, ledger=None, alerts=None,
                 retain_reports: Optional[int] = None):
        self.backend = backend
        self._handles: Dict[int, RequestHandle] = {}
        self._next_rid = 0
        self._on_event = on_event
        self._watchdog = watchdog
        self._stalled = 0           # consecutive no-progress pump rounds
        self._last_sig = None       # (now, total tokens) progress signature
        self.stuck = False          # set when the stall guard tripped
        if hasattr(backend, "events_on"):
            backend.events_on = on_event is not None
        # pull-side observability: MetricsRegistry / Tracer / EnergyLedger
        # handed to the backend's install_observability (every shipped
        # backend has one; all default None — the zero-overhead pattern).
        # ``alerts`` is a core.alerts.AlertEngine evaluated once per pump
        # round at the backend's clock (block cadence, timeline-pure).
        self.metrics = metrics
        self.tracer = tracer
        self.ledger = ledger
        self.alerts = alerts
        if (metrics is not None or tracer is not None
                or ledger is not None) \
                and hasattr(backend, "install_observability"):
            backend.install_observability(metrics, tracer, ledger)
        # long-lived-server retention: with retain_reports=N, only the N
        # most recently finished requests keep their handle / backend
        # bookkeeping (request row, TBT records) — older terminal requests
        # are evicted so a serve-forever process has bounded memory.
        # Evicted requests no longer appear in report(); None retains all.
        self._retain = retain_reports
        self._seen_terminal: set = set()
        self._terminal_order: deque = deque()

    # -- intake ----------------------------------------------------------------
    def submit(self, prompt, params: Optional[SamplingParams] = None, *,
               arrival: float = 0.0, deadline: float = -1.0,
               rid: Optional[int] = None) -> RequestHandle:
        """Submit one request.

        ``prompt`` is either a sequence of token ids (the real engines
        compute on them) or an int prompt length (tokens synthesized /
        simulator).  ``arrival`` is the request's arrival time on the
        backend's virtual clock — backends never start work before it.
        ``deadline`` (absolute, optional) is carried into the per-request
        report rows.  Sampling is fully per-request: ``params`` carries
        temperature / top-k / top-p / seed and rides the ``Request`` into
        the backend, whose jitted decode path keeps one sampling lane per
        batch slot — requests with different sampling configs share a
        batch (``temperature=None`` means greedy argmax, like 0).
        """
        params = params if params is not None else SamplingParams()
        if isinstance(prompt, (int, np.integer)):
            prompt_len, prompt_tokens = int(prompt), None
        else:
            prompt_tokens = np.asarray(prompt, np.int32)
            prompt_len = len(prompt_tokens)
        if rid is None:
            rid = self._next_rid
        if rid in self._handles:
            raise ValueError(f"duplicate rid {rid}")
        self._next_rid = max(self._next_rid, rid) + 1
        req = Request(rid=rid, arrival=arrival, prompt_len=prompt_len,
                      output_len=params.max_tokens, deadline=deadline,
                      sampling=params)
        self.backend.submit(req, prompt_tokens)
        handle = RequestHandle(self, req)
        self._handles[rid] = handle
        return handle

    # -- driving ----------------------------------------------------------------
    def _pump(self) -> bool:
        """Advance the backend one unit of work.  False when the backend is
        drained.  Handles observe progress directly through their request
        objects (token list + state); the buffered stream events are
        delivered to the ``on_event`` callback when one is installed and
        discarded otherwise (with no callback the backend skips buffering
        entirely — see ``__init__``)."""
        if self.stuck or not self.backend.has_work():
            self._deliver(self.backend.drain_events())
            return False
        self.backend.step()
        self._deliver(self.backend.drain_events())
        if self.alerts is not None:
            self.alerts.evaluate(self.backend.now)
        if self._retain is not None:
            self._retire()
        if self._watchdog is not None and not self._watch():
            self._deliver(self.backend.drain_events())
            return False
        return True

    def _retire(self) -> None:
        """Bound long-lived-server memory (``retain_reports``): record
        newly-terminal requests in finish order, then evict the oldest
        beyond the cap — the handle here and the per-request bookkeeping
        in the backend (``Backend.evict``: request row, TBT records)."""
        for rid, h in self._handles.items():
            if h.done and rid not in self._seen_terminal:
                self._seen_terminal.add(rid)
                self._terminal_order.append(rid)
        can_evict = hasattr(self.backend, "evict")
        while len(self._terminal_order) > self._retain:
            rid = self._terminal_order.popleft()
            self._seen_terminal.discard(rid)
            self._handles.pop(rid, None)
            if can_evict:
                self.backend.evict(rid)

    def _watch(self) -> bool:
        """Apply the watchdog policy after a pump round.  Returns False
        exactly when the stall guard declares the backend stuck (the driver
        loop stops; everything in flight has been failed cleanly)."""
        wd = self._watchdog
        now = self.backend.now
        if wd.request_budget_s != float("inf"):
            for h in self._handles.values():
                r = h.request
                if not r.state.terminal and now - r.arrival \
                        > wd.request_budget_s:
                    self.backend.fail(r.rid)
        if wd.stall_rounds > 0:
            sig = (now, sum(h.request.tokens_emitted
                            for h in self._handles.values()))
            if sig == self._last_sig and self.backend.has_work():
                self._stalled += 1
                if self._stalled >= wd.stall_rounds:
                    for h in self._handles.values():
                        if not h.request.state.terminal:
                            self.backend.fail(h.request.rid)
                    self.stuck = True
                    return False
            else:
                self._stalled = 0
                self._last_sig = sig
        return True

    def _deliver(self, events) -> None:
        if self._on_event is not None:
            for ev in events:
                self._on_event(ev)

    def run(self, max_rounds: int = 1_000_000) -> ServingReport:
        """The one driver loop: serve until the backend drains, then return
        the typed report.  (Interleave with ``handle.tokens()`` freely —
        streaming consumes the same loop.)"""
        rounds = 0
        while self._pump():
            rounds += 1
            if rounds >= max_rounds and self.backend.has_work():
                raise RuntimeError(
                    f"backend did not drain within {max_rounds} rounds")
        return self.report()

    def cancel(self, rid: int) -> bool:
        h = self._handles.get(rid)
        return h.cancel() if h is not None else self.backend.cancel(rid)

    def report(self) -> ServingReport:
        return self.backend.report()
