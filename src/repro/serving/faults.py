"""Deterministic fault injection for the serving cluster.

Production brings three failure shapes that GreenLLM's energy story must
survive: a replica dying mid-decode, a ``StreamHandoff`` import failing
transiently (network blip, momentary pool pressure on the adopter), and a
page-pool pressure spike (a co-tenant grabbing memory).  ``FaultPlan``
describes a schedule of such events on the cluster's *virtual* clock, so a
faulty run is exactly reproducible: same plan + same workload = same kills
at the same virtual times, same failed import attempts, same recovery
decisions — which is what lets tests assert bit-identical survivor tokens
against a no-fault run.

Usage::

    plan = FaultPlan([ReplicaKill(at=0.8, replica="decode1"),
                      HandoffFailure(at=0.0, count=3),
                      PagePressureSpike(at=0.5, duration=0.3,
                                        replica="decode0", pages=8)])
    cl = ServingCluster(cfg, ..., faults=plan)

or seeded::

    plan = FaultPlan.from_seed(7, horizon=2.0,
                               replicas=["prefill0", "decode0", "decode1"])

A ``FaultPlan`` carries mutable consumption state (which events already
fired); build a fresh plan (or call ``reset()``) for each run.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ReplicaKill:
    """Kill ``replica`` when the cluster clock reaches ``at``: its engine
    stops (energy frozen at the kill), and every stream it held — queued,
    mid-chunked-prefill, mid-decode, or parked in its import queue — is
    requeued at the dispatcher for recompute on a survivor."""
    at: float
    replica: str

    def describe(self):
        """(span_name, attrs) for the trace span emitted when this event
        fires (``core.tracing`` instant; the cluster adds fire time and
        runtime detail like victim count)."""
        return "replica_kill", {"at": self.at}


@dataclasses.dataclass(frozen=True)
class HandoffFailure:
    """Fail the next ``count`` ``StreamHandoff`` import attempts in the
    window ``[at, until)`` — on ``replica`` when named, on any replica
    otherwise.  The cluster retries with capped exponential backoff; the
    stream is never dropped."""
    at: float
    until: float = float("inf")
    replica: str = ""              # "" = any replica
    count: int = 1                 # attempts to fail inside the window

    def describe(self):
        """(span_name, attrs) for the trace span of one injected failure
        (the cluster emits it per failed attempt with rid and attempts)."""
        return "handoff_retry", {"count": self.count}


@dataclasses.dataclass(frozen=True)
class PagePressureSpike:
    """Withhold ``pages`` free pages from ``replica``'s pool for
    ``duration`` virtual seconds starting at ``at`` (an external memory
    squeeze).  The engine reacts with its normal pressure ladder — shrink
    decode blocks, preempt youngest, gate admission — and the pages return
    when the spike ends."""
    at: float
    duration: float
    replica: str
    pages: int

    def describe(self):
        """(span_name, attrs) for the trace spans at the spike's on/off
        edges (the cluster adds the ``edge`` attribute)."""
        return "page_pressure", {"pages": self.pages,
                                 "duration": self.duration}


class FaultPlan:
    """An ordered schedule of fault events, consumed by ``ServingCluster``.

    The plan is pure data plus consumption counters; all *reaction* logic
    (recovery, retry, preemption) lives in the cluster/engine.  ``reset()``
    rewinds the counters so the identical schedule can drive another run.
    """

    def __init__(self, events: Sequence[object] = ()):
        self.events = list(events)
        for ev in self.events:
            if not isinstance(ev, (ReplicaKill, HandoffFailure,
                                   PagePressureSpike)):
                raise TypeError(f"unknown fault event {ev!r}")
        self.reset()

    def reset(self) -> None:
        self._killed: set = set()          # ReplicaKill events fired
        self._fail_counts: dict = {}       # HandoffFailure -> attempts failed
        self._spikes_on: dict = {}         # PagePressureSpike -> pages taken
        self._spikes_done: set = set()
        self.log: List[tuple] = []         # (kind, time, detail) fired events

    # -- queries (called by the cluster) --------------------------------------
    def due_kills(self, now: float) -> List[ReplicaKill]:
        """Kills whose time has come and that have not fired yet."""
        out = []
        for ev in self.events:
            if isinstance(ev, ReplicaKill) and ev.at <= now \
                    and id(ev) not in self._killed:
                self._killed.add(id(ev))
                self.log.append(("kill", now, ev.replica))
                out.append(ev)
        return out

    def fail_import(self, replica: str, rid: int, now: float) -> bool:
        """Should this import attempt fail?  Consumes one failure budget
        from the first matching ``HandoffFailure`` window."""
        for ev in self.events:
            if not isinstance(ev, HandoffFailure):
                continue
            if ev.replica and ev.replica != replica:
                continue
            if not (ev.at <= now < ev.until):
                continue
            used = self._fail_counts.get(id(ev), 0)
            if used >= ev.count:
                continue
            self._fail_counts[id(ev)] = used + 1
            self.log.append(("import_fail", now, (replica, rid)))
            return True
        return False

    def pressure_changes(self, now: float):
        """Yield (event, 'on'|'off') transitions due at ``now`` — 'on' when
        the spike window opens, 'off' when it closes."""
        for ev in self.events:
            if not isinstance(ev, PagePressureSpike):
                continue
            key = id(ev)
            if key not in self._spikes_on and key not in self._spikes_done \
                    and ev.at <= now:
                self._spikes_on[key] = ev
                self.log.append(("pressure_on", now, ev.replica))
                yield ev, "on"
            if key in self._spikes_on and now >= ev.at + ev.duration:
                del self._spikes_on[key]
                self._spikes_done.add(key)
                self.log.append(("pressure_off", now, ev.replica))
                yield ev, "off"

    # -- construction ----------------------------------------------------------
    @classmethod
    def from_seed(cls, seed: int, *, horizon: float,
                  replicas: Sequence[str], n_kills: int = 1,
                  n_handoff_failures: int = 2,
                  n_pressure_spikes: int = 1,
                  max_spike_pages: int = 8) -> "FaultPlan":
        """A deterministic random plan: same seed + same arguments = the
        same schedule, every time (``np.random.default_rng`` is fully
        specified).  Kills target replicas other than the first one listed
        (something must survive to recover onto)."""
        rng = np.random.default_rng(seed)
        names = list(replicas)
        events: List[object] = []
        killable = names[1:] or names
        for _ in range(min(n_kills, len(killable))):
            victim = killable[int(rng.integers(len(killable)))]
            killable = [n for n in killable if n != victim]
            events.append(ReplicaKill(
                at=float(rng.uniform(0.1, 0.9) * horizon), replica=victim))
        for _ in range(n_handoff_failures):
            t = float(rng.uniform(0.0, 0.8) * horizon)
            events.append(HandoffFailure(
                at=t, until=t + float(rng.uniform(0.2, 0.6) * horizon),
                count=int(rng.integers(1, 4))))
        for _ in range(n_pressure_spikes):
            events.append(PagePressureSpike(
                at=float(rng.uniform(0.1, 0.7) * horizon),
                duration=float(rng.uniform(0.1, 0.4) * horizon),
                replica=names[int(rng.integers(len(names)))],
                pages=int(rng.integers(1, max_spike_pages + 1))))
        events.sort(key=lambda e: e.at)
        return cls(events)
