"""Training launcher.

Two modes:
  * ``--dry-run``: lower + compile the full config's train step against the
    production mesh (same path as dryrun.py) — for cluster preflight.
  * default: run real steps of the *smoke* variant on local devices — for
    CI / development.

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --dry-run
"""
import argparse
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp"])
    args = ap.parse_args()

    if args.dry_run:
        from repro.launch.dryrun import run_one
        run_one(args.arch, "train_4k", args.multi_pod,
                outdir="results/dryrun/manual", strategy=args.strategy)
        return

    import jax
    from repro.configs import get_config
    from repro.models import NOSHARD
    from repro.training import AdamWConfig, init_train_state, make_train_step

    cfg = get_config(args.arch).smoke()
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(warmup_steps=5, total_steps=args.steps), NOSHARD, 1))
    key = jax.random.PRNGKey(1)
    for i in range(args.steps):
        key, k = jax.random.split(key)
        batch = {"tokens": jax.random.randint(
            k, (args.batch, args.seq), 0, cfg.vocab_size)}
        if cfg.num_prefix_embeds:
            batch["prefix_embeds"] = jax.random.normal(
                k, (args.batch, cfg.num_prefix_embeds, cfg.d_model))
        state, m = step(state, batch)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.2f}")


if __name__ == "__main__":
    main()
