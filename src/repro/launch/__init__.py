from .mesh import make_production_mesh, make_debug_mesh, make_serving_mesh
