"""Driver: run every (arch x shape x mesh) dry-run in isolated subprocesses.

Each combo runs in a fresh process (jax device state is locked at first
init; isolation also bounds compile-cache memory growth).  Existing JSON
outputs are skipped unless --force.

  PYTHONPATH=src python -m repro.launch.dryrun_all [--mesh single|multi|both]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

from repro.configs import ARCH_IDS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--archs", default=None, help="comma-separated subset")
    ap.add_argument("--shapes", default="train_4k,prefill_32k,decode_32k,long_500k")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    archs = args.archs.split(",") if args.archs else ARCH_IDS
    shapes = args.shapes.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    t0 = time.time()
    for multi in meshes:
        outdir = os.path.join("results", "dryrun", "2x16x16" if multi else "16x16")
        for arch in archs:
            for shape in shapes:
                path = os.path.join(outdir, f"{arch}__{shape}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip] {path}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", outdir]
                if multi:
                    cmd.extend(["--multi-pod", "--no-extrapolate"])
                print(f"[run ] {' '.join(cmd[3:])}", flush=True)
                try:
                    r = subprocess.run(cmd, timeout=args.timeout,
                                       capture_output=True, text=True)
                    if r.returncode != 0:
                        failures.append((arch, shape, multi, r.stderr[-2000:]))
                        print(f"[FAIL] {arch} {shape} multi={multi}\n"
                              f"{r.stderr[-800:]}", flush=True)
                    else:
                        print(r.stdout.strip().splitlines()[-1], flush=True)
                except subprocess.TimeoutExpired:
                    failures.append((arch, shape, multi, "timeout"))
                    print(f"[TIMEOUT] {arch} {shape} multi={multi}", flush=True)
    print(f"\ndone in {time.time()-t0:.0f}s; {len(failures)} failures")
    for a, s, m, err in failures:
        print(f"  FAIL {a} x {s} multi={m}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
