import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh and record memory / cost /
collective analysis for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k --multi-pod
Outputs JSON to results/dryrun/<mesh>/<arch>__<shape>.json
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, build_step

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _result_bytes(line: str) -> int:
    """Sum byte sizes of the result shapes on an HLO instruction line."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0
    total = 0
    # result may be a tuple: take everything before the op name paren
    rhs = lhs[1]
    opidx = min((rhs.find(op) for op in COLLECTIVE_OPS if op in rhs),
                default=-1)
    typestr = rhs[:opidx] if opidx > 0 else rhs.split("(")[0]
    for m in _SHAPE_RE.finditer(typestr):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str):
    stats = {op: {"count": 0, "bytes": 0} for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        for op in COLLECTIVE_OPS:
            # match op as instruction name, e.g. "all-gather(", "all-reduce-start("
            if f" {op}(" in s or f" {op}-start(" in s or f" {op}-done(" in s:
                if f" {op}-done(" in s:
                    continue  # avoid double counting start/done pairs
                stats[op]["count"] += 1
                stats[op]["bytes"] += _result_bytes(s)
                break
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


def _compile_combo(cfg, shape, mesh, num_microbatches=None, strategy="tp"):
    from repro.launch.specs import build_train
    if shape.kind == "train" and (num_microbatches is not None
                                  or strategy != "tp"):
        built = build_train(cfg, shape, mesh, num_microbatches=num_microbatches,
                            strategy=strategy)
    else:
        built = build_step(cfg, shape, mesh)
    jf = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                 out_shardings=built["out_shardings"],
                 donate_argnums=built["donate_argnums"])
    with mesh:
        lowered = jf.lower(*built["args"])
        compiled = lowered.compile()
    return built, compiled


def _cost_of(compiled):
    cost = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text())
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll_bytes": coll["total_bytes"]}


def extrapolate_costs(cfg, shape, mesh, strategy="tp"):
    """XLA cost_analysis counts while-loop bodies once; recover full-depth
    per-step costs by diffing compiles at depth = pattern and 2 x pattern
    (with a single microbatch so the layer scan is the only loop that
    matters), then extrapolating linearly in layer-group count.
    """
    p = len(cfg.block_pattern)
    cfg1 = cfg.replace(num_layers=p, unroll_scans=True)
    cfg2 = cfg.replace(num_layers=2 * p, unroll_scans=True)
    _, c1 = _compile_combo(cfg1, shape, mesh, num_microbatches=1,
                           strategy=strategy)
    _, c2 = _compile_combo(cfg2, shape, mesh, num_microbatches=1,
                           strategy=strategy)
    a, b = _cost_of(c1), _cost_of(c2)
    n_groups = cfg.num_layers / p
    out = {}
    for k in a:
        per_group = b[k] - a[k]
        base = a[k] - per_group
        out[k] = base + per_group * n_groups
        out[k + "_per_group"] = per_group
        out[k + "_base"] = base
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, outdir: str,
            moe_impl: str = None, verbose: bool = True,
            kv_quant: bool = False, strategy: str = "tp",
            tag: str = "", extrapolate: bool = True,
            moe_group: int = None):
    cfg = get_config(arch)
    if moe_impl and cfg.is_moe:
        cfg = cfg.replace(moe_impl=moe_impl)
    if moe_group and cfg.is_moe:
        cfg = cfg.replace(moe_group=moe_group)
    if kv_quant:
        cfg = cfg.replace(kv_quant=True)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    built, compiled = _compile_combo(cfg, shape, mesh, strategy=strategy)
    t_compile = time.time() - t0
    t_lower = 0.0
    extrap = extrapolate_costs(cfg, shape, mesh, strategy=strategy) \
        if extrapolate else {}

    mem = compiled.memory_analysis()
    print(mem)
    cost = compiled.cost_analysis()
    print({k: cost.get(k) for k in ("flops", "bytes accessed", "transcendentals")})
    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    n_chips = 1
    for s in mesh.devices.shape:
        n_chips *= s

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": shape.kind,
        "meta": built["meta"],
        "variant": {"kv_quant": kv_quant, "strategy": strategy,
                    "moe_impl": moe_impl, "tag": tag},
        "moe_impl": cfg.moe_impl if cfg.is_moe else None,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": mem.argument_size_in_bytes
                + mem.output_size_in_bytes + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes,
        },
        "cost": {
            "flops_per_device": cost.get("flops", 0.0),
            "bytes_per_device": cost.get("bytes accessed", 0.0),
            "transcendentals": cost.get("transcendentals", 0.0),
        },
        # loop-trip-corrected per-device costs (see extrapolate_costs)
        "cost_extrapolated": extrap,
        "collectives": coll,
        "model": {
            "params_total": cfg.param_count(),
            "params_active": cfg.param_count(active_only=True),
        },
    }
    os.makedirs(outdir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(outdir, f"{arch}__{shape_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {result['mesh']}: "
              f"compile {t_compile:.1f}s  "
              f"peak/device {result['memory']['peak_bytes_per_device']/2**30:.2f} GiB  "
              f"flops/device {result['cost']['flops_per_device']:.3e}  "
              f"collective {coll['total_bytes']/2**20:.1f} MiB")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--moe-impl", default=None, choices=[None, "einsum", "scatter"])
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--moe-group", type=int, default=None)
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--tag", default="", help="suffix for perf-variant outputs")
    ap.add_argument("--no-extrapolate", action="store_true",
                    help="skip the cost-extrapolation compiles (multi-pod: "
                         "the roofline table is single-pod only)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    outdir = args.out or os.path.join(
        "results", "dryrun", "2x16x16" if args.multi_pod else "16x16")
    try:
        run_one(args.arch, args.shape, args.multi_pod, outdir, args.moe_impl,
                kv_quant=args.kv_quant, strategy=args.strategy, tag=args.tag,
                extrapolate=not args.no_extrapolate, moe_group=args.moe_group)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
