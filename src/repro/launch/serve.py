"""Serving launcher: drive any of the repo's data planes — single colocated
engine, paged engine, or the disaggregated prefill/decode cluster — through
the ``serving.api.Server`` front door, fed by a synthetic stream or a named
trace, and print the shared typed ``ServingReport``.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --requests 16
  PYTHONPATH=src python -m repro.launch.serve --governor defaultnv --paged
  PYTHONPATH=src python -m repro.launch.serve --cluster --trace azure_code8
  PYTHONPATH=src python -m repro.launch.serve --no-chunked --requests 8
  # heterogeneous batches: every second request samples at --temperature
  # (with optional --top-k / --top-p / --seed), the rest stay greedy
  PYTHONPATH=src python -m repro.launch.serve --mixed-sampling \
      --temperature 0.8 --top-k 40
  # observability: Prometheus snapshot + metrics timeline + request trace
  PYTHONPATH=src python -m repro.launch.serve --cluster \
      --metrics-out /tmp/metrics.prom --trace-out /tmp/trace.jsonl \
      --dashboard 0.25
  # per-request energy attribution + SLO alert rules
  PYTHONPATH=src python -m repro.launch.serve --cluster \
      --attribution-out /tmp/energy.jsonl --alerts
"""
import argparse
import json
import sys

import numpy as np

from repro.configs import get_config
from repro.core import (AlertEngine, AlertRule, EnergyLedger,
                        MetricsRegistry, SamplingParams, SLOConfig, Tracer,
                        verify_conservation)
from repro.serving import EngineConfig, Server, ServingCluster, ServingEngine


def build_backend(args, full, smoke):
    mesh = None
    if args.mesh:
        try:
            mesh = tuple(int(v) for v in args.mesh.split(","))
        except ValueError:
            raise SystemExit(f"--mesh expects 'dp,tp', got {args.mesh!r}")
    ecfg = EngineConfig(max_batch=args.max_batch, max_len=args.max_len,
                        governor=args.governor,
                        paged=args.paged or args.prefix_cache,
                        chunked_prefill=args.chunked,
                        prefix_cache=args.prefix_cache,
                        mesh=mesh)
    if args.cluster:
        # paged slot-native plane is forced by the cluster (KV handoff)
        return ServingCluster(smoke, n_prefill=1, n_decode=1,
                              plant_cfg=full, ecfg=ecfg)
    return ServingEngine(smoke, plant_cfg=full, ecfg=ecfg)


def sampling_for(args, i: int, max_tokens: int) -> SamplingParams:
    """Per-request sampling: greedy by default; ``--temperature`` samples
    every request, and ``--mixed-sampling`` restores greedy on the even
    ones (a multi-tenant-style heterogeneous batch)."""
    if args.temperature <= 0.0 or (args.mixed_sampling and i % 2 == 0):
        return SamplingParams(max_tokens=max_tokens)
    return SamplingParams(max_tokens=max_tokens,
                          temperature=args.temperature, top_k=args.top_k,
                          top_p=args.top_p,
                          seed=None if args.seed < 0 else args.seed + i)


def workload(args, vocab):
    """(arrival, prompt_tokens, max_tokens) triples: a named trace's
    arrival/length mix, or the synthetic burst."""
    rng = np.random.default_rng(0)
    # with --prefix-cache every prompt opens with the same system prefix
    # (the chat/RAG traffic shape the cache targets) so the dashboard's
    # hit rate reflects real sharing instead of random-prompt misses; the
    # tail is capped so the engine's keep-the-last-max_len/2 prompt
    # truncation never chops (and misaligns) the shared head
    sys_prompt = rng.integers(0, vocab, size=48) if args.prefix_cache \
        else np.empty(0, np.int64)
    cap = max(args.max_len // 2 - len(sys_prompt), 1)
    if args.trace != "synthetic":
        from repro.data import get_trace
        trace = get_trace(args.trace, duration=args.duration)
        for r in trace[: args.requests]:
            plen = min(r.prompt_len, args.max_len // 2, cap)
            yield (r.arrival,
                   np.concatenate([sys_prompt,
                                   rng.integers(0, vocab, size=plen)]),
                   min(r.output_len, args.max_len // 3))
    else:
        for _ in range(args.requests):
            plen = min(int(rng.integers(16, 80)), cap)
            yield (0.0,
                   np.concatenate([sys_prompt,
                                   rng.integers(0, vocab, size=plen)]),
                   int(rng.integers(16, 64)))


def default_alert_rules(slo: SLOConfig):
    """The ``--alerts`` rule set: TTFT/TBT error-budget burn rate over a
    trailing window plus a hard p95-TBT latency ceiling."""
    rules = [AlertRule.burn_rate(
        f"{kind}-burn", "greenllm_slo_total",
        bad_labels={"kind": kind, "outcome": "miss"},
        good_labels={"kind": kind, "outcome": "pass"},
        window_s=2.0, slo_target=0.9, burn_threshold=1.0, min_events=4,
        severity="page") for kind in ("ttft", "tbt")]
    rules.append(AlertRule.threshold(
        "p95-tbt-high", "greenllm_tbt_p95_seconds", ">",
        2.0 * slo.tbt_target, severity="warning"))
    return rules


class Dashboard:
    """Periodic one-line stderr dashboard, driven by the event stream's
    virtual timestamps — it fires when drained events cross the period
    boundary (the backend's block cadence), never per token."""

    def __init__(self, period: float, metrics: MetricsRegistry,
                 out=sys.stderr, alerts=None):
        self.period = period
        self.metrics = metrics
        self.out = out
        self.alerts = alerts
        self._next = period

    def __call__(self, ev) -> None:
        t = getattr(ev, "time", 0.0)
        while t >= self._next:
            self.line(self._next)
            self._next += self.period

    def line(self, t: float) -> None:
        flat = self.metrics.flat()

        def total(prefix, needle=""):
            return sum(v for k, v in flat.items()
                       if k.startswith(prefix) and needle in k)

        freqs = {k.split('replica="')[1].rstrip('"}'): v
                 for k, v in flat.items()
                 if k.startswith("greenllm_frequency_mhz")}
        p95 = max((v for k, v in flat.items()
                   if k.startswith("greenllm_tbt_p95_seconds")),
                  default=0.0)
        fstr = " ".join(f"{n}={f:.0f}" for n, f in sorted(freqs.items()))
        extra = ""
        saved = total("greenllm_energy_saved_joules_total")
        if saved:
            extra += f" saved={saved / 1e3:.2f}kJ"
        drops = total("greenllm_tracer_dropped")
        if drops:
            extra += f" trace_drops={drops:.0f}"
        pc_hits = total("greenllm_prefix_cache_hits_total")
        pc_miss = total("greenllm_prefix_cache_misses_total")
        if pc_hits + pc_miss:
            extra += f" pc_hit={100 * pc_hits / (pc_hits + pc_miss):.0f}%"
        if self.alerts is not None:
            firing = self.alerts.firing()
            if firing:
                extra += " ALERTS[" + ",".join(sorted(firing)) + "]"
        print(f"[serve t={t:8.3f}s] "
              f"done={total('greenllm_requests_total', 'completed'):.0f} "
              f"E={total('greenllm_energy_joules_total') / 1e3:.2f}kJ "
              f"p95_tbt={p95 * 1e3:5.1f}ms MHz[{fstr}]{extra}",
              file=self.out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--governor", default="greenllm",
                    choices=["greenllm", "defaultnv"])
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=192)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache (page-table data plane)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="content-addressed prompt prefix cache over the "
                         "paged pool (implies --paged); the synthetic "
                         "workload prepends a shared system prompt so the "
                         "dashboard's pc_hit%% shows real sharing")
    ap.add_argument("--chunked", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="chunked prefill admission (--no-chunked falls "
                         "back to eager reference prefill for long prompts)")
    ap.add_argument("--mesh", default="",
                    help="'dp,tp' serving mesh (e.g. 2,4): shard the data "
                         "plane over dp*tp devices — bit-identical to "
                         "single-device serving; on CPU export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=<dp*tp> "
                         "first")
    ap.add_argument("--cluster", action="store_true",
                    help="disaggregated 1-prefill + 1-decode cluster with "
                         "paged-KV handoff instead of one colocated engine")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for submitted requests "
                         "(0: greedy; per-request, not engine-global)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="per-request top-k filter (0: disabled)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="per-request nucleus mass (1.0: disabled)")
    ap.add_argument("--seed", type=int, default=-1,
                    help="base sampling seed; request i uses seed+i "
                         "(-1: unseeded lanes)")
    ap.add_argument("--mixed-sampling", action="store_true",
                    help="alternate greedy and sampled requests in one "
                         "batch (multi-tenant mix; needs --temperature)")
    ap.add_argument("--trace", default="synthetic",
                    help="synthetic | chat_5qps | azure_code8 | azure_conv5 "
                         "| ... (data.traces names; arrivals replayed on "
                         "the virtual clock)")
    ap.add_argument("--duration", type=float, default=60.0,
                    help="trace horizon in seconds (named traces only)")
    ap.add_argument("--metrics-out", default="",
                    help="write the Prometheus text exposition here at "
                         "exit, plus the full metrics timeline next to it "
                         "(<path>.timeline.jsonl)")
    ap.add_argument("--trace-out", default="",
                    help="write the request-lifecycle trace here as JSONL, "
                         "plus a Chrome/Perfetto trace next to it "
                         "(<path>.chrome.json)")
    ap.add_argument("--dashboard", type=float, default=0.0,
                    help="print a one-line stderr dashboard every N "
                         "virtual seconds (0: off; implies a metrics "
                         "registry)")
    ap.add_argument("--attribution-out", default="",
                    help="install the per-request energy ledger and write "
                         "its attribution rows here as JSONL at exit "
                         "(conservation-checked against the report)")
    ap.add_argument("--alerts", action="store_true",
                    help="evaluate the default SLO alert rule set (TTFT/"
                         "TBT burn rate + p95-TBT ceiling) at block "
                         "cadence; implies a metrics registry; firings "
                         "are audited against the timeline at exit")
    args = ap.parse_args(argv)

    full = get_config(args.arch)
    smoke = full.smoke()
    metrics = MetricsRegistry(snapshot_min_dt=0.005) \
        if args.metrics_out or args.dashboard > 0 or args.alerts else None
    tracer = Tracer() if args.trace_out else None
    ledger = EnergyLedger() if args.attribution_out else None
    alerts = AlertEngine(metrics, default_alert_rules(SLOConfig()),
                         tracer=tracer) if args.alerts else None
    on_event = Dashboard(args.dashboard, metrics, alerts=alerts) \
        if args.dashboard > 0 else None
    server = Server(build_backend(args, full, smoke), on_event=on_event,
                    metrics=metrics, tracer=tracer, ledger=ledger,
                    alerts=alerts)
    n = 0
    for arrival, prompt, max_tokens in workload(args, smoke.vocab_size):
        server.submit(prompt, sampling_for(args, n, max_tokens),
                      arrival=arrival)
        n += 1
    rep = server.run()
    plane = "cluster(1p+1d)" if args.cluster else \
        ("engine/paged" if args.paged else "engine")
    print(f"arch={args.arch} governor={args.governor} plane={plane} "
          f"trace={args.trace} requests={n}")
    print(rep.summary())
    for row in rep.replicas:
        print(f"  {row.name:10s} {row.role:9s} "
              f"E={row.energy_j / 1e3:6.2f}kJ "
              f"(pre {row.prefill_energy_j / 1e3:.2f} / "
              f"dec {row.decode_energy_j / 1e3:.2f} / "
              f"idle {row.idle_energy_j / 1e3:.2f}) "
              f"tok {row.prefill_tokens}/{row.decode_tokens} "
              f"handoffs {row.exported + row.imported} "
              f"clock {row.freq_mhz:.0f}MHz")
    if args.prefix_cache:
        engines = [r.engine for r in server.backend.replicas] \
            if args.cluster else [server.backend]
        for eng in engines:
            if eng.prefix_cache is None:
                continue
            st = eng.prefix_cache.stats()
            print(f"  prefix-cache[{eng.name}]: hit_rate="
                  f"{st['hit_rate'] * 100:.0f}% "
                  f"({st['hits']} hits / {st['misses']} misses, "
                  f"{st['hit_tokens']} prompt tokens served from cache, "
                  f"{st['entries']} pages resident, "
                  f"{st['evictions']} evictions)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as fh:
            fh.write(metrics.render_prometheus())
        lines = metrics.write_timeline_jsonl(
            args.metrics_out + ".timeline.jsonl")
        print(f"metrics: {args.metrics_out} "
              f"(+{lines} timeline snapshots)", file=sys.stderr)
    if args.trace_out:
        n_rec = tracer.write_jsonl(args.trace_out)
        tracer.write_chrome_trace(args.trace_out + ".chrome.json")
        print(f"trace: {args.trace_out} ({n_rec} records; chrome trace "
              f"next to it)", file=sys.stderr)
    if ledger is not None:
        rows = rep.replicas if rep.replicas else [dict(
            replica=server.backend.name,
            prefill_j=rep.prefill_energy_j, decode_j=rep.decode_energy_j,
            idle_j=rep.idle_energy_j)]
        verify_conservation(ledger, rows)
        top = sorted(rep.requests, key=lambda r: -r.energy_j)[:5]
        print("per-request attributed energy (top 5 by joules):")
        for r in top:
            print(f"  rid={r.rid:<4d} E={r.energy_j:8.1f}J  "
                  f"saved_vs_fmax={r.energy_saved_j:8.1f}J")
        with open(args.attribution_out, "w") as fh:
            for row in ledger.rows():
                fh.write(json.dumps(row) + "\n")
        print(f"attribution: {args.attribution_out} ({len(ledger.rows())} "
              f"rows; conservation verified)", file=sys.stderr)
    if alerts is not None:
        alerts.evaluate(server.backend.now)     # final round at drain
        audited = alerts.audit()
        fired = [a for a in alerts.log if a.fired]
        print(f"alerts: {len(fired)} firing transition(s), "
              f"{audited} audited against the timeline", file=sys.stderr)
        for a in fired:
            print(f"  [{a.severity}] {a.rule} @ t={a.t:.3f}s "
                  f"value={a.value:.4g}", file=sys.stderr)
    assert rep.completed == n, "launcher burst must drain completely"
    return rep


if __name__ == "__main__":
    main()
