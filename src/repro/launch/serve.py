"""Serving launcher: real-execution engine (reduced model) under the
GreenLLM or defaultNV governor, fed by a synthetic request stream.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --requests 16
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --governor defaultnv
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core import Request
from repro.serving import EngineConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--governor", default="greenllm",
                    choices=["greenllm", "defaultnv"])
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache (page-table data plane)")
    args = ap.parse_args()

    full = get_config(args.arch)
    cfg = full.smoke()
    eng = ServingEngine(cfg, plant_cfg=full,
                        ecfg=EngineConfig(max_batch=args.max_batch,
                                          max_len=192,
                                          governor=args.governor,
                                          paged=args.paged))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(rid=i, arrival=0.0,
                           prompt_len=int(rng.integers(16, 80)),
                           output_len=int(rng.integers(16, 64))))
    stats = eng.run_until_drained()
    print(f"arch={args.arch} governor={args.governor}")
    print(f"  completed      {stats['completed']}")
    print(f"  virtual time   {stats['vtime_s']:.2f} s")
    print(f"  node energy    {stats['energy_j']/1e3:.2f} kJ")
    print(f"  p95 TBT        {stats['p95_tbt_ms']:.1f} ms (SLO 100 ms)")
    print(f"  final clock    {stats['freq_mhz']:.0f} MHz")
    print(f"  E prefill/dec  {stats['prefill_energy_j']/1e3:.2f} / "
          f"{stats['decode_energy_j']/1e3:.2f} kJ")
    if args.paged:
        print(f"  pages          {stats['pages_used']}/{stats['pages_total']}"
              f" used, {stats['preempted']} preemptions")


if __name__ == "__main__":
    main()
