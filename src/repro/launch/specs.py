"""Assigned input shapes and step-function builders for the dry-run.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation); the
``build_*`` functions return (fn, args, in_shardings, out_shardings,
donate_argnums) ready for ``jax.jit(...).lower(...)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import (ModelConfig, ShardCtx, loss_fn, prefill,
                          decode_step, init_params)
from repro.training import AdamWConfig, make_train_step
from . import shardings as SH


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k":   InputShape("long_500k", "decode", 524_288, 1),
}

# archs whose long_500k decode uses the beyond-paper ring-buffer window
# (pure full-attention archs; see DESIGN.md §long_500k policy)
def needs_ring_override(cfg: ModelConfig) -> bool:
    from repro.models.config import FULL_ATTN, LOCAL_ATTN
    kinds = set(cfg.block_pattern)
    return kinds == {FULL_ATTN}


def token_seq_len(cfg: ModelConfig, shape: InputShape) -> int:
    """Token count after reserving room for stubbed prefix embeddings."""
    if shape.kind in ("train", "prefill") and cfg.num_prefix_embeds:
        return shape.seq_len - cfg.num_prefix_embeds
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step inputs (no allocation)."""
    B = shape.global_batch
    out: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        S = token_seq_len(cfg, shape)
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.num_prefix_embeds:
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_embeds, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return out


def batch_input_shardings(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    b_ax = SH.batch_axes_for(mesh, shape.global_batch)
    b = b_ax if b_ax else None
    sp: Dict[str, P] = {"tokens": P(b, None)}
    if shape.kind in ("train", "prefill") and cfg.num_prefix_embeds:
        sp["prefix_embeds"] = P(b, None, None)
    return sp


# -- builders -----------------------------------------------------------------------

def microbatches_for(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                     n_batch_shards: Optional[int] = None) -> int:
    """Per-device batch is split so layer-boundary activations stay bounded
    (~4k tokens per device per microbatch)."""
    per_dev = shape.global_batch // max(
        n_batch_shards if n_batch_shards is not None else
        SH._axis_size(mesh, SH.batch_axes_for(mesh, shape.global_batch)), 1)
    tokens_per_dev = per_dev * shape.seq_len
    mb = max(1, min(per_dev, round(tokens_per_dev / 4096)))
    while per_dev % mb:
        mb -= 1
    return mb


def build_train(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                num_microbatches: Optional[int] = None,
                strategy: str = "tp"):
    if strategy == "fsdp":
        # fully-sharded data parallel: batch over every mesh axis, params
        # gathered per layer (§Perf hillclimb for collective-bound train)
        all_axes = tuple(a for a in ("pod", "data", "model")
                         if a in mesh.axis_names)
        n_all = 1
        for a in all_axes:
            n_all *= mesh.shape[a]
        b_ax = all_axes if shape.global_batch % n_all == 0 else \
            SH.batch_axes_for(mesh, shape.global_batch)
        shd = ShardCtx(mesh=mesh, batch_axes=b_ax, model_axis=None)
        pspecs, pshapes = SH.fsdp_param_specs(cfg, mesh)
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        gspecs = pspecs
        n_shards = 1
        for a in b_ax:
            n_shards *= mesh.shape[a]
    else:
        shd = SH.make_shard_ctx(mesh, shape.global_batch)
        pspecs, pshapes = SH.model_param_specs(cfg, mesh)
        ospecs = SH.opt_state_specs(pspecs, pshapes, mesh)
        gspecs = ospecs["m"]
        n_shards = None
    mb = num_microbatches or microbatches_for(cfg, shape, mesh, n_shards)
    step = make_train_step(cfg, AdamWConfig(), shd, mb, grad_specs=gspecs)

    opt_shapes = {
        "m": jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), pshapes),
        "v": jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), pshapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state_shapes = {"params": pshapes, "opt": opt_shapes}
    state_specs = {"params": pspecs, "opt": ospecs}
    batch = input_specs(cfg, shape)
    batch_specs = batch_input_shardings(cfg, shape, mesh)
    if strategy == "fsdp":
        b = shd.batch_axes if shd.batch_axes else None
        batch_specs = {k: P(*((b,) + (None,) * (v.ndim - 1)))
                       for k, v in batch.items()}

    in_shardings = (SH.named(mesh, state_specs), SH.named(mesh, batch_specs))
    out_shardings = (SH.named(mesh, state_specs), None)
    return dict(fn=step, args=(state_shapes, batch),
                in_shardings=in_shardings, out_shardings=out_shardings,
                donate_argnums=(0,), meta={"microbatches": mb})


def build_prefill(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    shd = SH.make_shard_ctx(mesh, shape.global_batch)
    pspecs, pshapes = SH.model_param_specs(cfg, mesh)
    cspecs, cshapes = SH.cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
    batch = input_specs(cfg, shape)
    batch_specs = batch_input_shardings(cfg, shape, mesh)
    b_ax = SH.batch_axes_for(mesh, shape.global_batch)

    def prefill_step(params, caches, inputs):
        logits, caches, n = prefill(params, cfg, inputs["tokens"], caches,
                                    inputs.get("prefix_embeds"), shd)
        return logits, caches

    logits_spec = SH.sanitize_spec(P(b_ax if b_ax else None, "model"),
                                   (shape.global_batch, cfg.vocab_size), mesh)
    in_shardings = (SH.named(mesh, pspecs), SH.named(mesh, cspecs),
                    SH.named(mesh, batch_specs))
    out_shardings = (SH.named(mesh, logits_spec), SH.named(mesh, cspecs))
    return dict(fn=prefill_step, args=(pshapes, cshapes, batch),
                in_shardings=in_shardings, out_shardings=out_shardings,
                donate_argnums=(1,), meta={})


def build_decode(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    long_ctx = shape.name == "long_500k" and needs_ring_override(cfg)
    msize = mesh.shape.get("model", 1)
    kv_seq_sharded = cfg.has_attention and cfg.num_kv_heads % msize != 0
    shd = dataclasses.replace(SH.make_shard_ctx(mesh, shape.global_batch),
                              kv_seq_sharded=kv_seq_sharded)
    pspecs, pshapes = SH.model_param_specs(cfg, mesh)
    cspecs, cshapes = SH.cache_specs(cfg, mesh, shape.global_batch,
                                     shape.seq_len, long_context=long_ctx)
    batch = input_specs(cfg, shape)
    batch_specs = batch_input_shardings(cfg, shape, mesh)
    b_ax = SH.batch_axes_for(mesh, shape.global_batch)
    pos = shape.seq_len - 1

    def serve_step(params, caches, inputs):
        logits, caches = decode_step(params, cfg, inputs["tokens"], caches,
                                     jnp.asarray(pos, jnp.int32), shd)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, caches

    tok_spec = P(b_ax if b_ax else None, None)
    in_shardings = (SH.named(mesh, pspecs), SH.named(mesh, cspecs),
                    SH.named(mesh, batch_specs))
    out_shardings = (SH.named(mesh, tok_spec), SH.named(mesh, cspecs))
    return dict(fn=serve_step, args=(pshapes, cshapes, batch),
                in_shardings=in_shardings, out_shardings=out_shardings,
                donate_argnums=(1,), meta={"long_context": long_ctx})


def build_serving_decode(cfg: ModelConfig, mesh: Mesh, *,
                         max_batch: int = 8, max_len: int = 256,
                         page_size: int = 16, num_pages: int = 0):
    """Dry-run builder for one *serving* paged decode step under the PR 10
    mesh shardings: params storage-sharded (``serving_param_specs``) and
    gathered to replicated inside the step, the paged pool / page table /
    slot vectors sharded along 'data' — the same placement the engine's
    block kernels run with, lowerable without building an engine."""
    from repro.models import init_cache
    from repro.models.kvcache import STACKED_CAPACITY_AXIS
    B = max_batch
    n_pages_per = -(-max_len // page_size)
    pool = num_pages or (B * n_pages_per + 1)
    shd = SH.make_serving_shard_ctx(mesh)
    pspecs, pshapes = SH.serving_param_specs(cfg, mesh)
    cshapes = jax.eval_shape(
        lambda: init_cache(cfg, B, max_len, dtype=jnp.bfloat16,
                           paged_pool=(pool, page_size)))
    cspecs = SH.serving_cache_specs(cshapes, mesh)
    row = SH.sanitize_spec(P("data"), (B,), mesh)
    pt_spec = SH.sanitize_spec(P("data", None), (B, n_pages_per), mesh)
    batch = {
        "tok": jax.ShapeDtypeStruct((B,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
        "active": jax.ShapeDtypeStruct((B,), jnp.bool_),
        "page_table": jax.ShapeDtypeStruct((B, n_pages_per), jnp.int32),
    }
    batch_specs = {"tok": row, "pos": row, "active": row,
                   "page_table": pt_spec}

    def serving_step(params, caches, inputs):
        params = SH.gather_replicated(params, mesh)
        logits, caches = decode_step(params, cfg, inputs["tok"][:, None],
                                     caches, inputs["pos"], shd,
                                     page_table=inputs["page_table"],
                                     active=inputs["active"])
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, caches

    in_shardings = (SH.named(mesh, pspecs), SH.named(mesh, cspecs),
                    SH.named(mesh, batch_specs))
    out_shardings = (SH.named(mesh, row), SH.named(mesh, cspecs))
    return dict(fn=serving_step, args=(pshapes, cshapes, batch),
                in_shardings=in_shardings, out_shardings=out_shardings,
                donate_argnums=(1,),
                meta={"pool_pages": pool,
                      "capacity_axis": STACKED_CAPACITY_AXIS})


def build_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    if shape.kind == "train":
        return build_train(cfg, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh)
    return build_decode(cfg, shape, mesh)
