"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the single real CPU device.
"""
from __future__ import annotations

import jax


def _mk_mesh(shape, axes, devices):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (explicit Auto
    partitioning) only exists on newer releases — older ones are Auto-only,
    so dropping the kwarg is behavior-preserving, not a downgrade."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, devices=devices,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e pod) or 2x16x16 = 512 chips (2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return _mk_mesh(shape, axes, devices)


def make_debug_mesh(model: int = 1, data: int = 1):
    """Small mesh over the locally available devices (tests)."""
    n = model * data
    return _mk_mesh((data, model), ("data", "model"), jax.devices()[:n])


def make_serving_mesh(data: int = 1, model: int = 1):
    """(data, model) mesh for the sharded serving data plane.

    A cluster "replica" becomes a slice of this mesh: per-slot state, the
    page table, and the paged KV pool shard along ``data``; parameters are
    storage-sharded over the flattened axes (``launch.shardings.
    serving_param_specs``) and gathered to replicated at kernel entry, which
    keeps every mesh shape bit-identical to single-device serving (the PR 10
    invariant).  CI forces an 8-device CPU topology via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"serving mesh ({data},{model}) needs {n} devices, have "
            f"{len(devices)}; on CPU run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} (set before importing jax)")
    return _mk_mesh((data, model), ("data", "model"), devices[:n])
