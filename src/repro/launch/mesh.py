"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e pod) or 2x16x16 = 512 chips (2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(
        shape, axes, devices=devices,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(model: int = 1, data: int = 1):
    """Small mesh over the locally available devices (tests)."""
    n = model * data
    return jax.make_mesh(
        (data, model), ("data", "model"), devices=jax.devices()[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
