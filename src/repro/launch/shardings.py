"""Sharding rules for params, optimizer state, caches and step inputs.

All proposed specs go through ``sanitize`` which drops any mesh axis that
does not evenly divide the corresponding array dimension — this is what lets
one rule set cover kv_heads ∈ {1,2,4,8,32}, 12-head models, 50280-row vocabs,
etc. without per-arch special cases.
"""
from __future__ import annotations

import math
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import ModelConfig, ShardCtx, param_specs, stages_of
from repro.models.config import FULL_ATTN, LOCAL_ATTN, SSM, RGLRU
from repro.models import kvcache as KV


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    out = []
    for i, entry in enumerate(spec):
        if i >= len(shape):
            break
        size = _axis_size(mesh, entry)
        out.append(entry if size > 1 and shape[i] % size == 0 else None)
    return P(*out)


def sanitize_tree(specs, shapes, mesh: Mesh):
    return jax.tree.map(
        lambda s, sh: sanitize_spec(s, sh.shape, mesh), specs, shapes,
        is_leaf=lambda x: isinstance(x, P))


def batch_axes_for(mesh: Mesh, batch: int) -> Tuple[str, ...]:
    """Largest prefix of (pod, data) that divides the global batch."""
    axes: Tuple[str, ...] = ()
    if "pod" in mesh.axis_names and "data" in mesh.axis_names:
        if batch % (mesh.shape["pod"] * mesh.shape["data"]) == 0:
            return ("pod", "data")
    if "data" in mesh.axis_names and batch % mesh.shape["data"] == 0:
        return ("data",)
    return axes


def make_shard_ctx(mesh: Mesh, batch: int) -> ShardCtx:
    return ShardCtx(mesh=mesh, batch_axes=batch_axes_for(mesh, batch),
                    model_axis="model")


# -- params / optimizer state -------------------------------------------------------

def model_param_specs(cfg: ModelConfig, mesh: Mesh):
    from repro.models import init_params
    shd = ShardCtx(mesh=mesh)
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    specs = param_specs(cfg, shd)
    return sanitize_tree(specs, shapes, mesh), shapes


def fsdp_param_specs(cfg: ModelConfig, mesh: Mesh):
    """Fully-sharded params (ZeRO-3 / FSDP): every tensor sharded over the
    flattened ('data','model') axes on its largest divisible dim.  GSPMD
    inserts per-layer weight all-gathers; activations stay batch-sharded
    only.  This trades the 4 activation all-reduces per layer of tensor
    parallelism for one weight all-gather + grad reduce-scatter per layer —
    a large win when tokens-per-device is high (see EXPERIMENTS.md §Perf).
    """
    from repro.models import init_params
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    full = 1
    for a in ("data", "model"):
        full *= mesh.shape.get(a, 1)

    def spec_for(sh) -> P:
        dims = list(sh.shape)
        # largest dim first; per dim, the largest divisible axis set — so a
        # 151936-row embedding prefers vocab/16 over d_model/256 (keeps the
        # unembed contraction local instead of all-reducing logits)
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        for i in order:
            for axes, size in ((("data", "model"), full),
                               (("model",), mesh.shape.get("model", 1)),
                               (("data",), mesh.shape.get("data", 1))):
                if size > 1 and dims[i] % size == 0 and dims[i] >= size:
                    entries = [None] * len(dims)
                    entries[i] = axes if len(axes) > 1 else axes[0]
                    return P(*entries)
        return P(*([None] * len(dims)))

    specs = jax.tree.map(spec_for, shapes)
    return specs, shapes


def zero1_specs(specs, shapes, mesh: Mesh):
    """Additionally shard optimizer-state (and grad-accum) over 'data'."""
    dsize = mesh.shape.get("data", 1)

    def add_data(spec: P, shape) -> P:
        entries = list(spec) + [None] * (len(shape.shape) - len(spec))
        if dsize <= 1:
            return P(*entries)
        for i, e in enumerate(entries):
            if e is None and shape.shape[i] % dsize == 0 and shape.shape[i] >= dsize:
                entries[i] = "data"
                break
        return P(*entries)

    return jax.tree.map(add_data, specs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(pspecs, shapes, mesh: Mesh):
    z = zero1_specs(pspecs, shapes, mesh)
    return {"m": z, "v": z, "step": P()}


# -- caches --------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int,
                long_context: bool = False, dtype=jnp.bfloat16):
    """(specs, shapes) pytrees parallel to transformer.init_cache output."""
    b_ax = batch_axes_for(mesh, batch)
    b = b_ax if b_ax else None
    batch_sharded = bool(b_ax)
    msize = mesh.shape.get("model", 1)

    specs: List[Any] = []
    shapes: List[Any] = []
    for kinds, n_rep in stages_of(cfg):
        group_specs, group_shapes = [], []
        for kind in kinds:
            cs = jax.eval_shape(
                lambda kk=kind: KV.init_block_cache(cfg, kk, batch, max_len,
                                                    long_context, dtype))
            if kind in (FULL_ATTN, LOCAL_ATTN):
                kv_ok = cfg.num_kv_heads % msize == 0 and msize > 1
                if batch_sharded:
                    seq_ax = None if kv_ok else "model"
                    kv_ax = "model" if kv_ok else None
                else:
                    seq_ax = ("data", "model")
                    kv_ax = None
                sp = {"k": P(b, seq_ax, kv_ax, None),
                      "v": P(b, seq_ax, kv_ax, None)}
                if cfg.kv_quant:
                    sp["k_s"] = P(b, seq_ax, kv_ax, None)
                    sp["v_s"] = P(b, seq_ax, kv_ax, None)
            elif kind == SSM:
                sp = {"state": P(b, "model", None, None),
                      "conv": P(b, None, "model")}
            elif kind == RGLRU:
                sp = {"h": P(b, "model"),
                      "conv": P(b, None, "model")}
            else:
                raise ValueError(kind)
            # add leading stack dim
            sp = jax.tree.map(lambda s: P(*((None,) + tuple(s))), sp,
                              is_leaf=lambda x: isinstance(x, P))
            stacked = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct((n_rep,) + x.shape, x.dtype), cs)
            group_specs.append(sp)
            group_shapes.append(stacked)
        specs.append(tuple(group_specs))
        shapes.append(tuple(group_shapes))
    specs = jax.tree.map(lambda s, sh: sanitize_spec(s, sh.shape, mesh),
                         specs, shapes, is_leaf=lambda x: isinstance(x, P))
    return specs, shapes


# -- serving data plane -------------------------------------------------------------

def make_serving_shard_ctx(mesh: Mesh) -> ShardCtx:
    """ShardCtx for the sharded serving engine: activations shard along
    ``data`` only (``model_axis=None`` dissolves tensor-parallel activation
    constraints).  Batch rows are independent, so every sharded computation
    is bit-identical to its single-device twin — the PR 10 equivalence bar.
    ``batch_axes`` is fixed (not ``batch_axes_for``) because slot vectors,
    cache rows, and the page table all carry ``max_batch`` rows and
    ``EngineConfig`` validates divisibility up front."""
    return ShardCtx(mesh=mesh, batch_axes=("data",), model_axis=None)


def serving_param_specs(cfg: ModelConfig, mesh: Mesh):
    """(specs, shapes) for serving parameter *storage*: fsdp-style largest-
    divisible-dim sharding over the flattened ('data','model') axes, except
    MoE expert tensors, which shard their expert axis over 'model' so each
    expert's weights live on exactly one model shard (expert parallelism at
    rest).  Kernels gather to replicated at entry (``gather_replicated``) —
    pure data movement, so sharded serving stays bit-exact while the at-rest
    footprint scales down with the mesh."""
    specs, shapes = fsdp_param_specs(cfg, mesh)
    msize = mesh.shape.get("model", 1)
    if not (cfg.is_moe and msize > 1 and cfg.num_experts % msize == 0):
        return specs, shapes
    from repro.models.moe import is_expert_leaf

    def fix(path, spec, shape):
        if is_expert_leaf(cfg, path, shape.shape):
            entries = [None] * len(shape.shape)
            entries[1] = "model"        # stacked leaves: (n_rep, E, ...)
            return P(*entries)
        return spec

    specs = jax.tree_util.tree_map_with_path(
        fix, specs, shapes, is_leaf=lambda x: isinstance(x, P))
    return specs, shapes


def serving_cache_specs(caches, mesh: Mesh):
    """Specs paralleling a serving cache pytree (``init_cache`` output,
    paged or dense): the stacked capacity axis — batch rows of dense/ring/
    recurrent leaves, the *page* axis of paged pool leaves — shards along
    'data'; non-divisible leaves fall back to replicated."""
    ax = KV.STACKED_CAPACITY_AXIS

    def spec_for(x):
        if x.ndim > ax:
            entries = [None] * (ax + 1)
            entries[ax] = "data"
            return sanitize_spec(P(*entries), x.shape, mesh)
        return P()

    return jax.tree.map(spec_for, caches)


def shard_serving_caches(caches, mesh: Mesh):
    """Place a freshly-initialized serving cache pytree on the mesh."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        caches, serving_cache_specs(caches, mesh))


def gather_replicated(tree, mesh: Optional[Mesh]):
    """Constrain every leaf to replicated — the all-gather at kernel entry
    that turns storage-sharded params back into single-device-identical
    compute.  Data movement only: no cross-shard float reduction is
    introduced, which is what keeps mesh serving bitwise equal to the
    unsharded engine.  No-op without a mesh (the NOSHARD path)."""
    if mesh is None:
        return tree
    rep = NamedSharding(mesh, P())
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(x, rep), tree)


# -- step inputs ------------------------------------------------------------------------

def named(mesh: Mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))
