"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention [arXiv:2401.04088].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, window=4096.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", arch_type="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    block_pattern=("local",), window=4096,
    num_experts=8, experts_per_token=2, capacity_factor=1.25,
    rope_theta=1_000_000.0, max_seq=524_288,
)
