"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024, attention-free, d_ff=0, vocab=50280, ssm_state=128.
d_inner = 2*d_model = 2048, headdim=64 -> 32 SSD heads.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", arch_type="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    block_pattern=("ssm",), ssm_state=128, ssm_expand=2, ssm_headdim=64,
    ssm_chunk=256, conv_width=4, tie_embeddings=True, pos_embedding="none",
    max_seq=524_288,
)
