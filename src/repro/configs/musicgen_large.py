"""musicgen-large [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (MHA, kv=32) d_ff=8192 vocab=2048.  The EnCodec codec and
text-conditioning frontend are stubbed: ``input_specs`` supplies precomputed
conditioning embeddings (num_prefix_embeds); the backbone decodes audio tokens.
MusicGen uses LayerNorm + GELU MLPs and sinusoidal absolute positions.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", arch_type="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    norm="layer", act="gelu", glu=False, pos_embedding="sincos",
    num_prefix_embeds=64, tie_embeddings=False,
    max_seq=524_288,
)
