"""llava-next-mistral-7b [vlm] — anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Mistral-7B backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
The SigLIP/CLIP vision tower + projector are stubbed per the carve-out:
``input_specs`` provides 2880 precomputed anyres patch embeddings (5 tiles x
576 patches) which the backbone consumes as prefix embeddings.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", arch_type="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    num_prefix_embeds=2880, rope_theta=1_000_000.0, max_seq=524_288,
)
