"""chatglm3-6b [dense] — RoPE 2d (half-dim rotary), GQA [arXiv:2406.12793].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", arch_type="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2, head_dim=128,
    d_ff=13696, vocab_size=65024, qkv_bias=True,
    rotary_frac=0.5, max_seq=524_288,
)
