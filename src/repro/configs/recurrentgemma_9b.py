"""recurrentgemma-9b [hybrid] — RG-LRU + local attention 1:2 [arXiv:2402.19427].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; pattern
(rglru, rglru, local) with window 2048, lru_width=4096, GeGLU; 38 = 12x3 + 2
(tail of two recurrent layers).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", arch_type="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"), window=2048, lru_width=4096,
    conv_width=4, act="gelu", embed_scale=True, tie_embeddings=True,
    pos_embedding="rope", max_seq=524_288,
)
