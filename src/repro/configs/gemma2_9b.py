"""gemma2-9b [dense] — local+global alternating, logit softcap [arXiv:2408.00118].

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000; head_dim=256,
window=4096 on local layers, attn softcap 50, final softcap 30, GeGLU,
sandwich (post-block) norms, scaled embeddings.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", arch_type="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    block_pattern=("local", "full"), window=4096,
    attn_softcap=50.0, final_softcap=30.0, act="gelu",
    post_block_norm=True, embed_scale=True, tie_embeddings=True,
    rope_theta=10_000.0, max_seq=524_288,
)
