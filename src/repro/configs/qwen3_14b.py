"""qwen3-14b — the paper's dense evaluation model [arXiv:2505.09388].

40L d_model=5120 40H (GQA kv=8) head_dim=128 d_ff=17408 vocab=151936, QK-norm.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", arch_type="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=17408, vocab_size=151936, qk_norm=True,
    rope_theta=1_000_000.0, max_seq=524_288,
)
