"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) moe_d_ff=768 vocab=151936; head_dim=128
(Qwen3 decouples head_dim from d_model/num_heads); QK-norm.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", arch_type="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936, qk_norm=True,
    num_experts=128, experts_per_token=8, capacity_factor=1.25,
    rope_theta=1_000_000.0, max_seq=524_288,
)
