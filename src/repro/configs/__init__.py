"""Architecture config registry: ``get_config(arch_id)`` / ``--arch <id>``.

Ten assigned architectures + the paper's own evaluation model (qwen3-14b).
Every config cites its source in the module docstring.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models import ModelConfig

ARCH_IDS: List[str] = [
    "musicgen-large",
    "granite-8b",
    "qwen2-1.5b",
    "mamba2-370m",
    "qwen3-moe-30b-a3b",
    "llava-next-mistral-7b",
    "chatglm3-6b",
    "gemma2-9b",
    "mixtral-8x7b",
    "recurrentgemma-9b",
    "qwen3-14b",
]

ASSIGNED_ARCHS = ARCH_IDS[:10]

_MODULES: Dict[str, str] = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
