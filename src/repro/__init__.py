"""repro: production-grade JAX reproduction of GreenLLM (SLO-aware DVFS
for energy-efficient LLM serving) with a multi-architecture model zoo,
multi-pod distribution, and Pallas TPU kernels."""
__version__ = "1.0.0"
