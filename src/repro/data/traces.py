"""Production-trace synthesis (Alibaba ServeGen-like chat, Azure-2024-like
code/conversation) and replay utilities.

The real datasets are not redistributable inside this offline container, so
we synthesize traces matched to their *published characterizations*:

* Alibaba chat (ServeGen, arXiv:2505.09999): bursty arrivals (CV > 1,
  gamma inter-arrivals), log-normal prompt lengths with a mostly-short body
  and a long tail past 4k, moderate output lengths (chatty turns).
* Azure LLM inference 2024 (AzurePublicDataset): *code* slices have long
  prompts (IDE context, median in the thousands) with short completions;
  *conversation* slices have shorter prompts and longer, streamed outputs.
  The paper downsamples to 1/8-1/4 of cluster rate for one node; our
  ``azure_*5`` / ``azure_*8`` variants correspond to the 1/5 and 1/8 rates.

Every generator is seeded and returns plain ``Request`` lists, so trace
replays are exactly reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core import Request


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    name: str
    qps: float
    duration: float
    # gamma inter-arrival burstiness (shape k; k=1 -> Poisson, k<1 -> bursty)
    burst_k: float
    # lognormal prompt lengths
    prompt_mu: float
    prompt_sigma: float
    prompt_clip: tuple
    # lognormal output lengths
    out_mu: float
    out_sigma: float
    out_clip: tuple
    seed: int = 0


def synthesize(spec: TraceSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    n_est = int(spec.qps * spec.duration * 1.5) + 16
    gaps = rng.gamma(spec.burst_k, 1.0 / (spec.qps * spec.burst_k), n_est)
    arrivals = np.cumsum(gaps)
    arrivals = arrivals[arrivals < spec.duration]
    n = len(arrivals)
    plen = np.exp(rng.normal(spec.prompt_mu, spec.prompt_sigma, n))
    plen = np.clip(plen, *spec.prompt_clip).astype(int)
    olen = np.exp(rng.normal(spec.out_mu, spec.out_sigma, n))
    olen = np.clip(olen, *spec.out_clip).astype(int)
    return [Request(rid=i, arrival=float(arrivals[i]),
                    prompt_len=int(plen[i]), output_len=int(olen[i]))
            for i in range(n)]


def alibaba_chat(qps: float, duration: float = 300.0, seed: int = 0) -> List[Request]:
    return synthesize(TraceSpec(
        name=f"chat_{qps}qps", qps=qps, duration=duration,
        burst_k=0.6,                       # bursty
        prompt_mu=6.2, prompt_sigma=1.0,   # median ~490, tail past 4k
        prompt_clip=(16, 12288),
        out_mu=6.0, out_sigma=0.8,         # median ~400 output tokens
        out_clip=(16, 2048), seed=seed))


def azure_code(rate_divisor: int, duration: float = 300.0,
               seed: int = 1) -> List[Request]:
    """Azure 2024 code slice at 1/rate_divisor of cluster rate."""
    qps = {8: 1.6, 5: 2.6, 4: 3.2}.get(rate_divisor, 12.8 / rate_divisor)
    return synthesize(TraceSpec(
        name=f"azure_code{rate_divisor}", qps=qps, duration=duration,
        burst_k=0.8,
        prompt_mu=7.6, prompt_sigma=0.9,   # median ~2000, long IDE contexts
        prompt_clip=(128, 16384),
        out_mu=3.9, out_sigma=0.7,         # short completions (~50)
        out_clip=(4, 512), seed=seed))


def azure_conv(rate_divisor: int, duration: float = 300.0,
               seed: int = 2) -> List[Request]:
    qps = {8: 1.9, 5: 3.0, 4: 3.8}.get(rate_divisor, 15.0 / rate_divisor)
    return synthesize(TraceSpec(
        name=f"azure_conv{rate_divisor}", qps=qps, duration=duration,
        burst_k=1.0,
        prompt_mu=6.4, prompt_sigma=1.0,   # median ~600
        prompt_clip=(16, 8192),
        out_mu=5.6, out_sigma=0.7,         # streamed answers (~270)
        out_clip=(16, 2048), seed=seed))


TRACES = {
    **{f"chat_{q}qps": (lambda q=q: alibaba_chat(q)) for q in (1, 3, 5, 8, 10)},
    "azure_code5": lambda: azure_code(5),
    "azure_code8": lambda: azure_code(8),
    "azure_conv5": lambda: azure_conv(5),
    "azure_conv8": lambda: azure_conv(8),
}


def get_trace(name: str, duration: Optional[float] = None,
              seed: Optional[int] = None) -> List[Request]:
    if name.startswith("chat_") and name.endswith("qps"):
        q = float(name[len("chat_"):-len("qps")])
        return alibaba_chat(q, duration or 300.0, seed or 0)
    if name.startswith("azure_code"):
        return azure_code(int(name[len("azure_code"):]), duration or 300.0, seed or 1)
    if name.startswith("azure_conv"):
        return azure_conv(int(name[len("azure_conv"):]), duration or 300.0, seed or 2)
    raise KeyError(name)


def sinusoidal_decode_load(duration: float = 120.0, period: float = 40.0,
                           tps_min: float = 300.0, tps_max: float = 2400.0,
                           step: float = 0.5, seed: int = 3):
    """Synthetic sinusoidal decode TPS target (paper Fig. 1)."""
    t = np.arange(0.0, duration, step)
    tps = tps_min + (tps_max - tps_min) * 0.5 * (1 - np.cos(2 * np.pi * t / period))
    return t, tps
