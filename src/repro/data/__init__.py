from .traces import (get_trace, alibaba_chat, azure_code, azure_conv,
                     sinusoidal_decode_load, synthesize, TraceSpec, TRACES)
