from .plant import PlantModel
from .engine import ServingSimulator, NodeConfig, SimResult
from .profiling import (profile_prefill_latency, profile_power,
                        profile_decode_table)
from .replay import (ReplayConfig, replay, build_simulator, compute_metrics,
                     Metrics, make_plant_fn, slo_pass_metrics, GOVERNORS)
