"""Plant model: the serving node the controllers act on.

Latency is a roofline over *exact* per-config FLOP/byte counts (derived from
the same ModelConfig the real JAX models use, cross-checked against the
dry-run's compiled cost analysis): the compute term scales 1/f, the HBM term
does not — which is precisely what produces the paper's phase asymmetry
(prefill compute-bound, decode memory-bound) and the U-shaped energy curves
of Fig. 3 *without asserting them*.

The controllers never call into this module directly; they see only profiled
samples (with measurement noise) and online telemetry.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.hardware import HardwareProfile
from repro.models import ModelConfig


@dataclasses.dataclass
class PlantModel:
    cfg: ModelConfig
    hw: HardwareProfile
    n_chips: int = 1            # tensor-parallel degree of one worker
    prefill_mfu: float = 0.45   # achievable fraction of peak in prefill
    decode_mbu: float = 0.70    # achievable fraction of HBM bw in decode
    noise_sigma: float = 0.02
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._wbytes = self.cfg.param_count(active_only=True) * 2

    # ---- workload characterization ------------------------------------------------
    def prefill_flops(self, L: int) -> float:
        return L * self.cfg.flops_per_token(L, phase="prefill")

    def prefill_bytes(self, L: int) -> float:
        kv_write = L * self.cfg.decode_bytes_per_token(0, batch=10**9)
        act = 12 * L * self.cfg.d_model * self.cfg.num_layers  # activation traffic
        return self._wbytes + kv_write + act

    def decode_flops(self, batch: int, ctx: float) -> float:
        return batch * self.cfg.flops_per_token(int(ctx), phase="decode")

    def decode_bytes(self, batch: int, ctx: float) -> float:
        state = self.cfg.decode_bytes_per_token(int(ctx), batch=10**9)
        return self._wbytes + batch * state

    # ---- ground truth (noisy) --------------------------------------------------------
    def _noise(self) -> float:
        return float(np.exp(self._rng.normal(0.0, self.noise_sigma)))

    def prefill_latency(self, L: int, f: float) -> float:
        t = self.hw.latency(self.prefill_flops(L) / self.n_chips,
                            self.prefill_bytes(L) / self.n_chips,
                            f, mfu=self.prefill_mfu, mbu=self.decode_mbu)
        return t * self._noise()

    def decode_step_latency(self, batch: int, ctx: float, f: float) -> float:
        t = self.hw.latency(self.decode_flops(batch, ctx) / self.n_chips,
                            self.decode_bytes(batch, ctx) / self.n_chips,
                            f, mfu=self.prefill_mfu, mbu=self.decode_mbu)
        return t * self._noise()

    def active_power(self, flops: float, bytes_: float, f: float,
                     latency: float) -> float:
        """Node power (all chips of the worker) during an active interval."""
        p = self.hw.power(flops / self.n_chips, bytes_ / self.n_chips, f,
                          latency, mfu=self.prefill_mfu, mbu=self.decode_mbu)
        return p * self.n_chips * self._noise()

    @property
    def idle_power(self) -> float:
        return self.hw.p_idle * self.n_chips

    def prefill_power(self, L: int, f: float, latency: float) -> float:
        return self.active_power(self.prefill_flops(L), self.prefill_bytes(L),
                                 f, latency)

    def decode_power(self, batch: int, ctx: float, f: float,
                     latency: float) -> float:
        return self.active_power(self.decode_flops(batch, ctx),
                                 self.decode_bytes(batch, ctx), f, latency)
