"""Trace-replay harness: build a configured node (DefaultNV / PrefillSplit /
GreenLLM), replay a trace, and compute the paper's metrics (TTFT%, TBT%,
relative prefill/decode energy)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import (DualLoopController, DecodeControllerConfig,
                        MaxFreqController, FixedFreqController,
                        PrefillOptimizer, Request, SLOConfig, make_router)
from repro.core.hardware import HardwareProfile, A100_SXM4_40G
from repro.models import ModelConfig
from .engine import NodeConfig, ServingSimulator, SimResult
from .plant import PlantModel
from .profiling import (profile_decode_table, profile_power,
                        profile_prefill_latency)

GOVERNORS = ("defaultnv", "prefillsplit", "greenllm")


def make_plant_fn(cfg: ModelConfig, hw: HardwareProfile,
                  noise: float = 0.02) -> Callable[[int, int], PlantModel]:
    def fn(n_chips: int, seed: int) -> PlantModel:
        return PlantModel(cfg=cfg, hw=hw, n_chips=n_chips,
                          noise_sigma=noise, seed=seed)
    return fn


@dataclasses.dataclass
class ReplayConfig:
    governor: str = "greenllm"
    slo: SLOConfig = dataclasses.field(default_factory=SLOConfig)
    node: NodeConfig = dataclasses.field(default_factory=NodeConfig)
    fixed_freq: Optional[float] = None     # fixed-clock sweep (Fig. 3c)
    latency_fit_degree: int = 2            # 1 for attention-free archs


def build_simulator(cfg: ModelConfig, hw: HardwareProfile,
                    rc: ReplayConfig) -> ServingSimulator:
    plant_fn = make_plant_fn(cfg, hw)
    gov = rc.governor.lower()
    assert gov in GOVERNORS or gov == "fixed", gov
    router = make_router(enabled=(gov != "defaultnv"))

    if gov == "greenllm":
        # offline profiling pass (the controllers' only plant knowledge)
        pplant = plant_fn(rc.node.prefill_chips, 7)
        lat = profile_prefill_latency(pplant, degree=rc.latency_fit_degree)
        pwr = profile_power(pplant)
        opt = PrefillOptimizer(lat, pwr, hw, hw.p_idle)
        popts = [opt] * rc.node.prefill_workers
        dplant = plant_fn(rc.node.decode_chips, 8)
        table_proto = profile_decode_table(dplant, rc.slo.tbt_target)

        def dctl(i: int):
            table = dataclasses.replace(
                table_proto, freq_for=table_proto.freq_for.copy())
            return DualLoopController(
                hw, table,
                DecodeControllerConfig(tbt_slo=rc.slo.tbt_target))
    elif gov == "fixed":
        popts = None

        def dctl(i: int):
            return FixedFreqController(hw, rc.fixed_freq)
    else:
        popts = None

        def dctl(i: int):
            return MaxFreqController(hw)

    sim = ServingSimulator(plant_fn, router, popts, dctl, rc.slo, rc.node)
    if gov == "fixed":
        for w in sim.prefill:
            w.freq = rc.fixed_freq
            w.choose_freq = lambda now, job=None, f=rc.fixed_freq: f
    return sim


@dataclasses.dataclass
class Metrics:
    ttft_pass: float
    tbt_pass: float
    prefill_energy_j: float
    decode_energy_j: float
    total_energy_j: float
    p90_ttft: Dict[str, float]
    p95_tbt: float
    p99_tbt: float
    n_requests: int
    throughput_tok_s: float


def compute_metrics(res: SimResult, slo: SLOConfig) -> Metrics:
    done = [r for r in res.requests if r.first_token >= 0]
    ttft_ok = sum(1 for r in done if r.ttft <= slo.ttft_target(r.cls))
    tbt_ok, total = 0, 0
    all_tbt: List[float] = []
    for r in done:
        tbts = res.tbt_records.get(r.rid, [])
        if not tbts:
            continue
        total += 1
        p95 = float(np.percentile(tbts, 95))
        all_tbt.extend(tbts)
        if p95 <= slo.tbt_target:
            tbt_ok += 1
    p90 = {}
    for cls in ("SM", "L"):
        v = [r.ttft for r in done if r.cls == cls]
        if v:
            p90[cls] = float(np.percentile(v, 90))
    tokens = sum(r.tokens_emitted for r in res.requests)
    return Metrics(
        ttft_pass=ttft_ok / max(len(done), 1),
        tbt_pass=tbt_ok / max(total, 1),
        prefill_energy_j=res.prefill_energy_j,
        decode_energy_j=res.decode_energy_j,
        total_energy_j=res.total_energy_j,
        p90_ttft=p90,
        p95_tbt=float(np.percentile(all_tbt, 95)) if all_tbt else 0.0,
        p99_tbt=float(np.percentile(all_tbt, 99)) if all_tbt else 0.0,
        n_requests=len(res.requests),
        throughput_tok_s=tokens / max(res.duration, 1e-9),
    )


def replay(cfg: ModelConfig, trace: List[Request], rc: ReplayConfig,
           hw: HardwareProfile = A100_SXM4_40G) -> Metrics:
    import copy
    sim = build_simulator(cfg, hw, rc)
    res = sim.run([copy.copy(r) for r in trace])
    return compute_metrics(res, rc.slo)
