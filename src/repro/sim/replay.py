"""Trace-replay harness: build a configured node (DefaultNV / PrefillSplit /
GreenLLM), replay a trace, and compute the paper's metrics (TTFT%, TBT%,
relative prefill/decode energy)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core import (DualLoopController, DecodeControllerConfig,
                        MaxFreqController, FixedFreqController,
                        PrefillOptimizer, Request, SLOConfig, make_router)
# single scoring definition, shared with the serving backends' report();
# re-exported here because it historically lived in this module
from repro.core.report import slo_pass_metrics  # noqa: F401
from repro.core.hardware import HardwareProfile, A100_SXM4_40G
from repro.models import ModelConfig
from .engine import NodeConfig, ServingSimulator, SimResult
from .plant import PlantModel
from .profiling import (profile_decode_table, profile_power,
                        profile_prefill_latency)

GOVERNORS = ("defaultnv", "prefillsplit", "greenllm")


def make_plant_fn(cfg: ModelConfig, hw: HardwareProfile,
                  noise: float = 0.02) -> Callable[[int, int], PlantModel]:
    def fn(n_chips: int, seed: int) -> PlantModel:
        return PlantModel(cfg=cfg, hw=hw, n_chips=n_chips,
                          noise_sigma=noise, seed=seed)
    return fn


@dataclasses.dataclass
class ReplayConfig:
    governor: str = "greenllm"
    slo: SLOConfig = dataclasses.field(default_factory=SLOConfig)
    node: NodeConfig = dataclasses.field(default_factory=NodeConfig)
    fixed_freq: Optional[float] = None     # fixed-clock sweep (Fig. 3c)
    latency_fit_degree: int = 2            # 1 for attention-free archs


def build_simulator(cfg: ModelConfig, hw: HardwareProfile,
                    rc: ReplayConfig) -> ServingSimulator:
    plant_fn = make_plant_fn(cfg, hw)
    gov = rc.governor.lower()
    assert gov in GOVERNORS or gov == "fixed", gov
    router = make_router(enabled=(gov != "defaultnv"))

    if gov == "greenllm":
        # offline profiling pass (the controllers' only plant knowledge)
        pplant = plant_fn(rc.node.prefill_chips, 7)
        lat = profile_prefill_latency(pplant, degree=rc.latency_fit_degree)
        pwr = profile_power(pplant)
        opt = PrefillOptimizer(lat, pwr, hw, hw.p_idle)
        popts = [opt] * rc.node.prefill_workers
        dplant = plant_fn(rc.node.decode_chips, 8)
        table_proto = profile_decode_table(dplant, rc.slo.tbt_target)

        def dctl(i: int):
            table = dataclasses.replace(
                table_proto, freq_for=table_proto.freq_for.copy())
            return DualLoopController(
                hw, table,
                DecodeControllerConfig(tbt_slo=rc.slo.tbt_target))
    elif gov == "fixed":
        popts = None

        def dctl(i: int):
            return FixedFreqController(hw, rc.fixed_freq)
    else:
        popts = None

        def dctl(i: int):
            return MaxFreqController(hw)

    sim = ServingSimulator(plant_fn, router, popts, dctl, rc.slo, rc.node)
    if gov == "fixed":
        for w in sim.prefill:
            w.freq = rc.fixed_freq
            w.choose_freq = lambda now, job=None, f=rc.fixed_freq: f
    return sim


@dataclasses.dataclass
class Metrics:
    ttft_pass: float
    tbt_pass: float
    prefill_energy_j: float
    decode_energy_j: float
    total_energy_j: float
    p90_ttft: Dict[str, float]
    p95_tbt: float
    p99_tbt: float
    n_requests: int
    throughput_tok_s: float


def compute_metrics(res: SimResult, slo: SLOConfig) -> Metrics:
    m = slo_pass_metrics(res.requests, res.tbt_records, slo)
    tokens = sum(r.tokens_emitted for r in res.requests)
    return Metrics(
        ttft_pass=m["ttft_pass"],
        tbt_pass=m["tbt_pass"],
        prefill_energy_j=res.prefill_energy_j,
        decode_energy_j=res.decode_energy_j,
        total_energy_j=res.total_energy_j,
        p90_ttft=m["p90_ttft"],
        p95_tbt=m["p95_tbt"],
        p99_tbt=m["p99_tbt"],
        n_requests=len(res.requests),
        throughput_tok_s=tokens / max(res.duration, 1e-9),
    )


def replay(cfg: ModelConfig, trace: List[Request], rc: ReplayConfig,
           hw: HardwareProfile = A100_SXM4_40G) -> Metrics:
    import copy
    sim = build_simulator(cfg, hw, rc)
    res = sim.run([copy.copy(r) for r in trace])
    return compute_metrics(res, rc.slo)

# ``metrics_from_cluster`` is gone: every backend (engine, cluster,
# simulator) now returns the same typed ``core.ServingReport`` from
# ``report()``, so there is no per-caller stats-dict to adapt.
