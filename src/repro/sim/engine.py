"""Discrete-event serving-node simulator.

Topology follows the paper's prototype (Fig. 4): a router feeding per-class
prefill queues, a prefill pool (default 2 workers x 2 chips) and a decode
pool (default 4 workers x 1 chip) doing continuous batching.  Controllers
(per-worker) are plugged in by the governor configuration:

  DefaultNV    : single queue, every clock pinned at f_max
  PrefillSplit : length-based routing only, clocks at f_max
  GreenLLM     : routing + queueing-aware prefill optimizer + dual-loop
                 decode controller

Energy is integrated per worker: active intervals at the plant's utilization-
dependent power, gaps at idle power.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (CounterfactualPricer, DualLoopController,
                        LengthRouter, MaxFreqController, PrefillOptimizer,
                        Request, RequestState, SLOConfig, ServingReport,
                        StateEvent, TokenEvent, build_report)
from repro.core.prefill_optimizer import deadline_from_queue
from .plant import PlantModel


class EnergyMeter:
    def __init__(self, idle_power: float):
        self.idle_power = idle_power
        self.active_j = 0.0
        self.idle_j = 0.0
        self._last_busy_end = 0.0

    def record_active(self, start: float, dur: float, power: float):
        """Bill one active interval; returns ``(active_j, idle_j)`` billed
        by this call so an attribution ledger can mirror the identical
        floats (the conservation invariant is bitwise)."""
        idle = 0.0
        if start > self._last_busy_end:
            idle = (start - self._last_busy_end) * self.idle_power
            self.idle_j += idle
        act = dur * power
        self.active_j += act
        self._last_busy_end = max(self._last_busy_end, start + dur)
        return act, idle

    def finalize(self, horizon: float):
        """Extend idle billing to ``horizon``; returns the idle joules this
        call added (monotone — repeated calls bill only the extension)."""
        idle = 0.0
        if horizon > self._last_busy_end:
            idle = (horizon - self._last_busy_end) * self.idle_power
            self.idle_j += idle
            self._last_busy_end = horizon
        return idle

    @property
    def total_j(self) -> float:
        return self.active_j + self.idle_j


class PrefillWorker:
    # reserve headroom below the TTFT deadline for the first decode step +
    # dispatch, and for arrival burstiness (queueing-awareness, Fig. 6)
    DEADLINE_SAFETY = 0.72
    FIRST_TOKEN_RESERVE = 0.060  # s

    def __init__(self, wid: str, plant: PlantModel,
                 optimizer: Optional[PrefillOptimizer], slo_ttft: float):
        self.wid = wid
        self.plant = plant
        self.optimizer = optimizer
        self.slo_ttft = slo_ttft
        self.queue: List[Request] = []
        self.busy_until = 0.0
        self.freq = plant.hw.f_max
        self.energy = EnergyMeter(plant.idle_power)
        self.freq_history: List[Tuple[float, float]] = []
        # EWMA arrival statistics for the queueing-aware work forecast
        self._rate = 0.0           # arrivals/s
        self._mean_tref = 0.0      # s at f_ref
        self._last_arrival: Optional[float] = None
        # DVFS decision log sink: cb(t, phase, freq_mhz, reason, **inputs)
        self.on_decision = None

    def observe_arrival(self, now: float, t_ref_job: float) -> None:
        if self._last_arrival is not None:
            gap = max(now - self._last_arrival, 1e-3)
            # EWMA of the *gap* (not 1/gap, which is biased high under
            # bursty gamma arrivals), inverted to a rate estimate
            self._gap = 0.85 * getattr(self, "_gap", gap) + 0.15 * gap
            self._rate = 1.0 / max(self._gap, 1e-3)
        self._last_arrival = now
        self._mean_tref = (0.9 * self._mean_tref + 0.1 * t_ref_job
                           if self._mean_tref else t_ref_job)

    def choose_freq(self, now: float, job: Optional[Request] = None) -> float:
        if self.optimizer is None:
            return self.plant.hw.f_max
        jobs = ([job] if job is not None else []) + self.queue
        lengths = [r.prompt_len for r in jobs]
        oldest = now - min((r.arrival for r in jobs), default=now)
        D = deadline_from_queue(lengths, self.slo_ttft, oldest)
        D = max(self.DEADLINE_SAFETY * D - self.FIRST_TOKEN_RESERVE, 1e-3)
        # forecast work arriving within the window (queueing-aware, §3.2):
        # inflate the pending work by lambda * D * E[t_ref] expressed as
        # equivalent prompt tokens via a synthetic-length job list.
        f, info = self.optimizer.choose_frequency(lengths, D)
        reason = info["reason"]
        # bound the slowdown committed to any single job: once started a job
        # cannot be sped up, so cap its own latency at 60% of its class SLO
        if lengths:
            t0 = float(self.optimizer.latency_model.t_ref(max(lengths)))
            ladder = self.optimizer.hw.ladder()
            ok = ladder[t0 * self.optimizer.latency_model.f_ref / ladder
                        <= 0.6 * self.slo_ttft]
            floor = float(ok[0]) if len(ok) else float(ladder[-1])
            if floor > f:
                f, reason = floor, "job_slo_floor"
        if self._rate > 0 and self._mean_tref > 0:
            # queueing stability: keep utilization rho = lambda * E[t(f)]
            # under 0.85 so arriving work does not accumulate unboundedly
            rho_target = 0.85
            f_ref = self.optimizer.latency_model.f_ref
            f_stab = min(f_ref * self._rate * self._mean_tref / rho_target,
                         self.plant.hw.f_max)
            if f_stab > f:
                f, reason = f_stab, "stability_floor"
        if self.on_decision is not None:
            self.on_decision(now, "prefill", f, reason,
                             n_jobs=len(lengths), D=D, busy=info["busy"])
        return f


class DecodeStream:
    __slots__ = ("req", "ctx")

    def __init__(self, req: Request, ctx: int):
        self.req = req
        self.ctx = ctx


class DecodeWorker:
    def __init__(self, wid: str, plant: PlantModel, controller,
                 max_streams: int = 64):
        self.wid = wid
        self.plant = plant
        self.controller = controller
        self.max_streams = max_streams
        self.streams: List[DecodeStream] = []
        self.pending: List[Request] = []
        self.energy = EnergyMeter(plant.idle_power)
        self.stepping = False

    @property
    def load(self) -> int:
        return len(self.streams) + len(self.pending)

    def admit(self):
        while self.pending and len(self.streams) < self.max_streams:
            r = self.pending.pop(0)
            self.streams.append(DecodeStream(r, r.prompt_len))


@dataclasses.dataclass
class NodeConfig:
    prefill_workers: int = 2
    prefill_chips: int = 2
    decode_workers: int = 4
    decode_chips: int = 1
    max_streams: int = 256  # KV-slot budget per decode worker
    prefill_replan_period: float = 0.05


@dataclasses.dataclass
class SimResult:
    requests: List[Request]
    prefill_energy_j: float
    decode_energy_j: float
    duration: float
    tbt_records: Dict[int, List[float]]
    freq_traces: Dict[str, List[Tuple[float, float, float]]]

    @property
    def total_energy_j(self) -> float:
        return self.prefill_energy_j + self.decode_energy_j


class ServingSimulator:
    """Discrete-event serving node, steppable one event at a time.

    Conforms to the ``serving.api.Backend`` protocol (``submit`` / ``step``
    / ``drain_events`` / ``cancel`` / ``report``): requests can arrive, be
    cancelled, and stream (count-only) token events while the simulation is
    in flight — the same driver loop serves the simulator and the
    real-execution engines.  ``run(requests)`` keeps the batch interface
    used by ``sim.replay.replay``.
    """

    def __init__(self, plant_fn: Callable[[int, int], PlantModel],
                 router: LengthRouter,
                 prefill_optimizers: Optional[Sequence[Optional[PrefillOptimizer]]],
                 decode_controller_fn: Callable[[int], object],
                 slo: SLOConfig, node: NodeConfig = NodeConfig(),
                 metrics=None, tracer=None, ledger=None):
        """plant_fn(n_chips, seed) builds a worker's plant model."""
        self.router = router
        self.slo = slo
        self.node = node
        self.prefill: List[PrefillWorker] = []
        for i in range(node.prefill_workers):
            cls = router.class_names[min(i, router.num_classes - 1)]
            opt = None if prefill_optimizers is None else \
                prefill_optimizers[min(i, len(prefill_optimizers) - 1)]
            self.prefill.append(PrefillWorker(
                f"prefill{i}", plant_fn(node.prefill_chips, 100 + i), opt,
                slo.ttft_target(cls)))
        self.decode: List[DecodeWorker] = [
            DecodeWorker(f"decode{i}", plant_fn(node.decode_chips, 200 + i),
                         decode_controller_fn(i), node.max_streams)
            for i in range(node.decode_workers)]
        self.tbt_records: Dict[int, List[float]] = {}
        self.requests: List[Request] = []
        self._evq: List[Tuple[float, int, str, object]] = []
        self._seq = 0
        self._last_time = 0.0
        self._events: List = []
        # False -> skip event buffering (serving.api.Server clears this
        # unless an on_event callback is installed)
        self.events_on = True
        # observability sinks (same zero-overhead pattern): per-worker
        # metric children and DVFS decision callbacks, published at the
        # discrete-event cadence — the simulator has no device to sync
        self.metrics = None
        self.tracer = None
        self.ledger = None
        self._cf: Dict[str, CounterfactualPricer] = {}
        self._m = None
        self._pub: Dict[Tuple[str, str], float] = {}
        if metrics is not None or tracer is not None or ledger is not None:
            self.install_observability(metrics, tracer, ledger)

    # -- observability -----------------------------------------------------------
    def install_observability(self, metrics=None, tracer=None,
                              ledger=None) -> None:
        """Backend observability surface: bind per-worker metric children,
        per-controller DVFS decision callbacks, and (optionally) a shared
        attribution ledger with a per-worker counterfactual pricer.
        ``None`` leaves a sink uninstalled; with none installed every
        emission site reduces to one ``is None`` check."""
        self.metrics = metrics
        self.tracer = tracer
        if ledger is not None:
            self.ledger = ledger
            for w in self.prefill + self.decode:
                ledger.register(w.wid)
                self._cf[w.wid] = CounterfactualPricer(w.plant)
        if tracer is not None:
            for w in self.prefill:
                w.on_decision = tracer.bind(w.wid)
            for d in self.decode:
                d.controller.on_decision = tracer.bind(d.wid)
        if metrics is not None:
            self._init_metrics(metrics)

    def _init_metrics(self, reg) -> None:
        """Same metric names as the serving engines (stable API): worker-
        scoped series carry the worker id as the ``replica`` label;
        node-wide lifecycle counters and latency histograms use ``node``."""
        ev = reg.counter("greenllm_requests_total",
                         "request lifecycle events", ("replica", "event"))
        e = reg.counter("greenllm_energy_joules_total",
                        "energy by phase (virtual-clock accounting)",
                        ("replica", "phase"))
        freq = reg.gauge("greenllm_frequency_mhz",
                         "controller SM clock set point", ("replica",))
        q = reg.gauge("greenllm_queue_depth",
                      "streams by lifecycle stage", ("replica", "queue"))
        self._m = {
            "ev": {k: ev.labels(replica="node", event=k) for k in
                   ("submitted", "completed", "cancelled", "failed",
                    "shed")},
            "ttft": reg.histogram("greenllm_ttft_seconds",
                                  "time to first token", ("replica",),
                                  buckets=(0.05, 0.1, 0.2, 0.4, 0.8, 1.6,
                                           3.2, 6.4)).labels(replica="node"),
            "tbt": reg.histogram("greenllm_tbt_seconds",
                                 "time between tokens", ("replica",),
                                 buckets=(0.005, 0.01, 0.02, 0.04, 0.08,
                                          0.1, 0.15, 0.25, 0.5))
                      .labels(replica="node"),
        }
        for w in self.prefill:
            self._m[w.wid] = {
                "freq": freq.labels(replica=w.wid),
                "e_act": e.labels(replica=w.wid, phase="prefill"),
                "e_idle": e.labels(replica=w.wid, phase="idle"),
                "q": q.labels(replica=w.wid, queue="pending"),
            }
        for d in self.decode:
            self._m[d.wid] = {
                "freq": freq.labels(replica=d.wid),
                "e_act": e.labels(replica=d.wid, phase="decode"),
                "e_idle": e.labels(replica=d.wid, phase="idle"),
                "q": q.labels(replica=d.wid, queue="pending"),
                "q_act": q.labels(replica=d.wid, queue="active"),
            }
        self._pub = {}

    def _pub_energy(self, wid: str, meter: EnergyMeter, m: Dict) -> None:
        for key, total in (("e_act", meter.active_j),
                           ("e_idle", meter.idle_j)):
            d = total - self._pub.get((wid, key), 0.0)
            if d > 0:
                m[key].inc(d)
                self._pub[(wid, key)] = total

    def _publish(self, now: float) -> None:
        """Publish worker gauges + energy counter deltas and snapshot the
        registry (rides the event cadence)."""
        if self._m is None:
            return
        for w in self.prefill:
            m = self._m[w.wid]
            m["freq"].set(w.freq)
            m["q"].set(len(w.queue))
            self._pub_energy(w.wid, w.energy, m)
        for d in self.decode:
            m = self._m[d.wid]
            m["freq"].set(d.controller.freq)
            m["q"].set(len(d.pending))
            m["q_act"].set(len(d.streams))
            self._pub_energy(d.wid, d.energy, m)
        self.metrics.record_snapshot(now)

    # -- prefill routing -----------------------------------------------------------
    def _prefill_worker_for(self, cls_idx: int, rid: int) -> PrefillWorker:
        if self.router.num_classes == 1:
            # single queue shared across the pool: pick least backlog
            return min(self.prefill, key=lambda w: (len(w.queue), w.busy_until))
        per_class = max(1, len(self.prefill) // self.router.num_classes)
        base = cls_idx * per_class
        cands = self.prefill[base: base + per_class] or self.prefill[-1:]
        return min(cands, key=lambda w: (len(w.queue), w.busy_until))

    # -- Backend protocol --------------------------------------------------------
    def submit(self, req: Request, prompt_tokens=None) -> None:
        """Queue a request for its arrival time (``prompt_tokens`` is
        accepted for interface parity and ignored: the simulator models
        time/energy, not token values)."""
        req.state = RequestState.QUEUED
        self.requests.append(req)
        self._push(req.arrival, "arrival", req)
        if self._m is not None:
            self._m["ev"]["submitted"].inc()
        if self.tracer is not None:
            self.tracer.instant("submit", req.rid, req.arrival,
                                prompt_len=req.prompt_len)

    def has_work(self) -> bool:
        return bool(self._evq)

    @property
    def now(self) -> float:
        """Backend protocol: the simulator clock (latest processed event)."""
        return self._last_time

    def cancel(self, rid: int) -> bool:
        """Cancel a request anywhere short of completion: drop it from
        prefill queues / decode pending / live decode batches.  A prefill
        already in flight runs to completion (its energy is spent) but the
        stream is dropped at ``prefill_done``."""
        return self._terminate(rid, RequestState.CANCELLED)

    def fail(self, rid: int) -> bool:
        """Give up on a request (``Backend.fail``): same release as
        ``cancel`` with the FAILED terminal state — simulator parity with
        the real-execution backends."""
        return self._terminate(rid, RequestState.FAILED)

    def _terminate(self, rid: int, state: RequestState) -> bool:
        for req in self.requests:
            if req.rid == rid:
                break
        else:
            return False
        if req.state.terminal:
            return False
        req.state = state
        for w in self.prefill:
            if req in w.queue:
                w.queue.remove(req)
        for d in self.decode:
            if req in d.pending:
                d.pending.remove(req)
            for s in list(d.streams):
                if s.req is req:
                    d.streams.remove(s)
        self._emit(StateEvent(rid, self._last_time, state))
        cancelled = state == RequestState.CANCELLED
        if self._m is not None:
            self._m["ev"]["cancelled" if cancelled else "failed"].inc()
        if self.tracer is not None:
            self.tracer.instant("cancel" if cancelled else "fail", rid,
                                self._last_time)
        return True

    def evict(self, rid: int) -> bool:
        """Backend protocol: drop a *terminal* request's bookkeeping
        (request row + TBT records).  Returns False (and removes nothing)
        while the request is still live."""
        req = next((q for q in self.requests if q.rid == rid), None)
        if req is None:
            return self.tbt_records.pop(rid, None) is not None
        if not req.state.terminal:
            return False
        self.requests.remove(req)
        self.tbt_records.pop(rid, None)
        return True

    def _emit(self, ev) -> None:
        if self.events_on:
            self._events.append(ev)

    def drain_events(self) -> List:
        ev, self._events = self._events, []
        return ev

    def step(self) -> bool:
        """Process one discrete event; False when the queue is empty."""
        if not self._evq:
            return False
        now, _, kind, payload = heapq.heappop(self._evq)
        self._last_time = max(self._last_time, now)
        if kind == "arrival":
            self._on_arrival(now, payload)
        elif kind == "prefill_done":
            self._on_prefill_done(now, *payload)
        elif kind == "decode_step_done":
            self._on_decode_step_done(now, *payload)
        return True

    def report(self) -> ServingReport:
        """Typed report over everything simulated so far.  Worker energy
        meters fold idle into the pool totals (``EnergyMeter``), so the
        phase fields match ``compute_metrics`` and ``idle_energy_j`` is 0.
        """
        self._finalize_energy()
        led = {}
        if self.ledger is not None:
            led = dict(energy_by_rid=self.ledger.energy_by_rid(),
                       saved_by_rid=self.ledger.saved_by_rid(),
                       energy_saved_j=self.ledger.saved_total_j())
        return build_report(
            backend="simulator", requests=self.requests,
            tbt_records=self.tbt_records, slo=self.slo,
            class_names=self.router.class_names,
            prefill_energy_j=sum(w.energy.total_j for w in self.prefill),
            decode_energy_j=sum(w.energy.total_j for w in self.decode),
            idle_energy_j=0.0,
            prefill_tokens=sum(r.prompt_len for r in self.requests
                               if r.prefill_start >= 0),
            decode_tokens=sum(r.tokens_emitted for r in self.requests),
            duration_s=self._last_time, **led)

    # -- event plumbing -----------------------------------------------------------
    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._evq, (t, self._seq, kind, payload))
        self._seq += 1

    def _start_prefill_if_idle(self, w: PrefillWorker, now: float) -> None:
        if w.busy_until > now or not w.queue:
            return
        w.queue.sort(key=lambda r: r.arrival)
        # deadline-aware admission (parity with ServingEngine): a request
        # whose absolute deadline already passed when it reaches the head
        # of the prefill queue is SHED, not served
        req = None
        while w.queue:
            cand = w.queue.pop(0)
            if cand.deadline >= 0 and now > cand.deadline:
                cand.state = RequestState.SHED
                self._emit(StateEvent(cand.rid, now, RequestState.SHED))
                if self._m is not None:
                    self._m["ev"]["shed"].inc()
                if self.tracer is not None:
                    self.tracer.instant("shed", cand.rid, now,
                                        replica=w.wid,
                                        deadline=cand.deadline)
                continue
            req = cand
            break
        if req is None:
            return
        w.freq = w.choose_freq(now, req)
        w.freq_history.append((now, w.freq))
        dur = w.plant.prefill_latency(req.prompt_len, w.freq)
        power = w.plant.prefill_power(req.prompt_len, w.freq, dur)
        act, idle = w.energy.record_active(now, dur, power)
        if self.ledger is not None:
            # mirror the exact floats the meter just billed (bitwise
            # conservation); the prefilling request is the only resident
            if idle:
                self.ledger.record_idle(w.wid, idle)
            self.ledger.record_prefill(
                w.wid, req.rid, act, tokens=req.prompt_len,
                saved_j=self._cf[w.wid].prefill_j(req.prompt_len) - act)
        req.prefill_start = now
        req.state = RequestState.PREFILLING
        self._emit(StateEvent(req.rid, now, RequestState.PREFILLING))
        w.busy_until = now + dur
        self._push(now + dur, "prefill_done", (w, req))
        if self.tracer is not None:
            self.tracer.span("queue", req.rid, req.arrival, now,
                             replica=w.wid)
            self.tracer.span("prefill", req.rid, now, now + dur,
                             replica=w.wid, tokens=req.prompt_len)
        self._publish(now)

    def _schedule_decode_step(self, w: DecodeWorker, now: float) -> None:
        if w.stepping:
            return
        w.admit()
        if not w.streams:
            return
        w.stepping = True
        f = w.controller.maybe_tick(now)
        batch = len(w.streams)
        avg_ctx = float(np.mean([s.ctx for s in w.streams]))
        dur = w.plant.decode_step_latency(batch, avg_ctx, f)
        power = w.plant.decode_power(batch, avg_ctx, f, dur)
        act, idle = w.energy.record_active(now, dur, power)
        if self.ledger is not None:
            # split the step across the streams resident when the energy
            # was committed (a cancel before step-done doesn't unbill)
            if idle:
                self.ledger.record_idle(w.wid, idle)
            self.ledger.record_decode(
                w.wid, [s.req.rid for s in w.streams], act,
                saved_j=self._cf[w.wid].decode_j(batch, avg_ctx) - act)
        self._push(now + dur, "decode_step_done", (w, dur, batch))

    # -- event handlers -----------------------------------------------------------
    def _on_arrival(self, now: float, req: Request) -> None:
        if req.state.terminal:          # cancelled before arrival
            return
        cls_idx = self.router.route(req)
        w = self._prefill_worker_for(cls_idx, req.rid)
        w.queue.append(req)
        if w.optimizer is not None:
            w.observe_arrival(
                now, float(w.optimizer.latency_model.t_ref(req.prompt_len)))
        self._start_prefill_if_idle(w, now)

    def _on_prefill_done(self, now: float, w: PrefillWorker,
                         req: Request) -> None:
        if not req.state.terminal:      # cancelled mid-prefill: drop stream
            req.state = RequestState.DECODING
            self._emit(StateEvent(req.rid, now, RequestState.DECODING))
            dw = min(self.decode, key=lambda d: d.load)
            dw.pending.append(req)
            self._schedule_decode_step(dw, now)
        self._start_prefill_if_idle(w, now)

    def _on_decode_step_done(self, now: float, w: DecodeWorker, dur: float,
                             batch: int) -> None:
        w.stepping = False
        done: List[DecodeStream] = []
        for s in w.streams:
            s.req.tokens_emitted += 1
            s.ctx += 1
            if s.req.first_token < 0:
                s.req.first_token = now
                if self._m is not None:
                    self._m["ttft"].observe(max(now - s.req.arrival, 0.0))
            self.tbt_records.setdefault(s.req.rid, []).append(dur)
            self._emit(TokenEvent(s.req.rid, now, (), 1))
            if s.req.tokens_emitted >= s.req.output_len:
                s.req.finish = now
                s.req.state = RequestState.FINISHED
                self._emit(StateEvent(s.req.rid, now,
                                      RequestState.FINISHED))
                done.append(s)
                if self._m is not None:
                    self._m["ev"]["completed"].inc()
                if self.tracer is not None:
                    self.tracer.instant("finish", s.req.rid, now,
                                        replica=w.wid,
                                        tokens=s.req.tokens_emitted)
        for s in done:
            w.streams.remove(s)
        w.controller.record_tokens(now, batch, dur)
        if self._m is not None:
            self._m["tbt"].observe(dur, batch)
        self._publish(now)
        self._schedule_decode_step(w, now)

    def _finalize_energy(self) -> None:
        # EnergyMeter.finalize is monotone in the horizon, so calling it at
        # every report() only extends idle up to the latest event time
        for w in self.prefill + self.decode:
            idle = w.energy.finalize(self._last_time)
            if self.ledger is not None and idle:
                self.ledger.record_idle(w.wid, idle)

    # -- batch interface (sim.replay) ---------------------------------------------
    def run(self, requests: Sequence[Request]) -> SimResult:
        for r in requests:
            self.submit(r)
        while self.step():
            self._events.clear()     # no consumer in the batch interface
        self._finalize_energy()
        freq_traces = {}
        for w in self.decode:
            if hasattr(w.controller, "history"):
                freq_traces[w.wid] = list(w.controller.history)
        for w in self.prefill:
            freq_traces[w.wid] = [(t, f, 0.0) for t, f in w.freq_history]
        return SimResult(
            requests=list(requests),
            prefill_energy_j=sum(w.energy.total_j for w in self.prefill),
            decode_energy_j=sum(w.energy.total_j for w in self.decode),
            duration=self._last_time,
            tbt_records=self.tbt_records,
            freq_traces=freq_traces,
        )
