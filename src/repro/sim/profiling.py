"""Microbenchmark profiling (paper §2.2.1): the controllers' only window
into the plant.  Mirrors the paper's two trace-based microbenchmarks:

* Prefill microbenchmark: length-randomized prompts, one decoded token;
  sweeps SM clock; yields the quadratic latency fit (Fig. 7) and, driven at
  saturation with fixed-length prompts, the cubic power fit (Fig. 8).
* Decode microbenchmark: short prefill then decode at target TPS levels
  maintained by adjusting concurrency; yields P95-TBT and energy-per-token
  surfaces over (TPS, f) from which the TPS->frequency table is built.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

from repro.core import (CubicPowerModel, QuadraticLatencyModel, TPSFreqTable)
from repro.core.hardware import HardwareProfile
from .plant import PlantModel


def profile_prefill_latency(plant: PlantModel, f_ref: float = None,
                            lengths: Sequence[int] = None, reps: int = 3,
                            degree: int = 2) -> QuadraticLatencyModel:
    f_ref = f_ref or plant.hw.f_max
    if lengths is None:
        lengths = np.unique(np.geomspace(32, 8192, 24).astype(int))
    Ls, ts = [], []
    for L in lengths:
        for _ in range(reps):
            Ls.append(L)
            ts.append(plant.prefill_latency(int(L), f_ref))
    return QuadraticLatencyModel.fit(Ls, ts, f_ref, degree=degree)


def profile_power(plant: PlantModel, sat_len: int = 1024,
                  freqs: np.ndarray = None) -> CubicPowerModel:
    """Drive prefill at saturation (fixed 1024-token prompts, high QPS),
    sweep the SM clock, record power (paper Fig. 8)."""
    hw = plant.hw
    freqs = hw.ladder()[::2] if freqs is None else freqs
    Ps = []
    for f in freqs:
        t = plant.prefill_latency(sat_len, f)
        Ps.append(plant.prefill_power(sat_len, f, t) / plant.n_chips)
    return CubicPowerModel.fit(freqs, Ps, hw.f_max, hw.p_idle)


def profile_decode_table(plant: PlantModel, tbt_slo: float = 0.100,
                         tps_levels: Sequence[float] = None,
                         gen_ctx: Tuple[int, int] = (256, 1024)
                         ) -> TPSFreqTable:
    """Decode microbenchmark: for each target TPS, adjust concurrency to hold
    the rate, sweep clocks, record P95 TBT and energy/token (paper §3.3.1)."""
    hw = plant.hw
    if tps_levels is None:
        tps_levels = [100, 200, 400, 700, 1000, 1400, 1800, 2400, 3000]
    freqs = hw.ladder()[::2]
    ctx = int(np.mean(gen_ctx))
    p95 = np.zeros((len(tps_levels), len(freqs)))
    ept = np.zeros_like(p95)
    for i, tps in enumerate(tps_levels):
        for j, f in enumerate(freqs):
            # concurrency needed to sustain `tps` given per-step latency
            batch = 1
            for _ in range(24):
                t = plant.decode_step_latency(batch, ctx, f)
                need = int(np.ceil(tps * t))
                if need <= batch:
                    break
                batch = min(max(need, batch + 1), 512)
            t = plant.decode_step_latency(batch, ctx, f)
            p95[i, j] = t * 1.05           # step latency == TBT for the batch
            power = plant.decode_power(batch, ctx, f, t)
            ept[i, j] = power * t / max(batch, 1)
    return TPSFreqTable.from_profile(tps_levels, freqs, p95, ept,
                                     tbt_slo, hw.f_step)
