"""End-to-end behaviour tests: trace replay vs the paper's headline claims.

These replays are shortened (90-120 s) versions of the paper's >=30 min runs,
so thresholds are set at the conservative edges of the paper's reported
ranges (Tables 3-4: 6.8-34 % energy savings; <3.5 % SLO-violation increase;
PrefillSplit ~= +/-3 % energy with tighter TTFT tails).
"""
import copy

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SLOConfig
from repro.core.hardware import A100_SXM4_40G, TPU_V5E
from repro.data import get_trace
from repro.sim import ReplayConfig, replay


@pytest.fixture(scope="module")
def results():
    cfg = get_config("qwen3-14b")
    trace = get_trace("chat_5qps", duration=90)
    out = {}
    for gov in ("defaultNV", "prefillsplit", "greenllm"):
        out[gov] = replay(cfg, trace, ReplayConfig(governor=gov))
    return out


def test_greenllm_saves_energy(results):
    base = results["defaultNV"].total_energy_j
    green = results["greenllm"].total_energy_j
    saving = 1 - green / base
    assert 0.10 <= saving <= 0.45, f"saving {saving:.2%} outside paper envelope"


def test_greenllm_preserves_slos(results):
    base = results["defaultNV"]
    green = results["greenllm"]
    # paper: <3.5% SLO violation increase
    assert green.ttft_pass >= base.ttft_pass - 0.035
    assert green.tbt_pass >= base.tbt_pass - 0.035
    assert green.tbt_pass >= 0.93


def test_greenllm_preserves_throughput(results):
    base = results["defaultNV"].throughput_tok_s
    green = results["greenllm"].throughput_tok_s
    assert green >= 0.95 * base


def test_prefillsplit_is_routing_only(results):
    """Routing alone: small energy delta, TTFT tail no worse."""
    base = results["defaultNV"]
    ps = results["prefillsplit"]
    delta = abs(1 - ps.total_energy_j / base.total_energy_j)
    assert delta <= 0.05
    assert ps.ttft_pass >= base.ttft_pass


def test_decode_energy_is_where_savings_come_from(results):
    """Paper: decode falls to 0.62-0.73x default; prefill also drops."""
    base = results["defaultNV"]
    green = results["greenllm"]
    rel_decode = green.decode_energy_j / base.decode_energy_j
    assert rel_decode < 0.85


def test_savings_shrink_with_load():
    """Paper Table 3: savings decrease as QPS rises toward saturation."""
    cfg = get_config("qwen3-14b")
    savings = {}
    for qps in (1, 10):
        trace = get_trace(f"chat_{qps}qps", duration=90)
        base = replay(cfg, trace, ReplayConfig(governor="defaultNV"))
        green = replay(cfg, trace, ReplayConfig(governor="greenllm"))
        savings[qps] = 1 - green.total_energy_j / base.total_energy_j
    assert savings[10] <= savings[1] + 0.02, savings


def test_moe_model_also_saves():
    """Paper Table 4 (Qwen3-30B-MoE): savings 10-31%."""
    cfg = get_config("qwen3-moe-30b-a3b")
    trace = get_trace("azure_conv5", duration=90)
    base = replay(cfg, trace, ReplayConfig(governor="defaultNV"))
    green = replay(cfg, trace, ReplayConfig(governor="greenllm"))
    saving = 1 - green.total_energy_j / base.total_energy_j
    assert 0.05 <= saving <= 0.45
    assert green.tbt_pass >= 0.93


def test_portable_to_tpu_profile():
    """The control plane is hardware-agnostic: same stack on the TPU v5e
    profile still saves energy under SLOs (DESIGN.md §2)."""
    cfg = get_config("qwen3-14b")
    trace = get_trace("chat_3qps", duration=90)
    base = replay(cfg, trace, ReplayConfig(governor="defaultNV"), hw=TPU_V5E)
    green = replay(cfg, trace, ReplayConfig(governor="greenllm"), hw=TPU_V5E)
    assert green.total_energy_j < base.total_energy_j
    assert green.ttft_pass >= base.ttft_pass - 0.05


def test_margin_sensitivity_direction():
    """Paper §5.3: looser prefill margins -> less energy, higher TTFT."""
    cfg = get_config("qwen3-14b")
    trace = get_trace("chat_5qps", duration=90)
    tight = replay(cfg, trace, ReplayConfig(
        governor="greenllm", slo=SLOConfig(prefill_margin=0.6)))
    loose = replay(cfg, trace, ReplayConfig(
        governor="greenllm", slo=SLOConfig(prefill_margin=2.0)))
    assert loose.prefill_energy_j <= tight.prefill_energy_j * 1.02
    assert loose.p90_ttft.get("SM", 0) >= tight.p90_ttft.get("SM", 0) * 0.9
