"""Prefix-cache subsystem tests (ROADMAP item 3): content-addressed sharing
of page-aligned prompt chunks over the paged KV pool.

The headline guarantee is the strong one: a request whose prompt *hits* the
cache (adopting another stream's physical pages via ``share_chain`` and
prefilling only the tail) emits tokens **bit-identical** to the same request
served cold — greedy rows because f32 rows are batch-independent, seeded
rows because the per-stream RNG lane folds in absolute position only.  Like
tests/test_paging.py, every equivalence run therefore pins model compute and
K/V storage to float32: a hit routes through chunked prefill while the cold
twin may one-shot, two summation orders that agree bitwise in f32 but differ
by an ulp in bf16.

Below the engine, ``PrefixCache`` unit tests pin the digest-chain contract
(one divergent token kills every later page's match) and the eviction rules
(LRU over unreferenced leaves only — never a page a live chain still holds),
and the allocator property storm extends tests/test_paging.py's invariants
to refcounted sharing: conservation, ref == holders, no aliasing, no leaks.
The storm runs under hypothesis when available and falls back to seeded
numpy randomness (same invariants, fixed corpus) when not.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EnergyLedger, Request, RequestState, SamplingParams,
                        verify_conservation)
from repro.models import decode_step, init_cache, init_params, prefill
from repro.models.config import ModelConfig
from repro.serving import (EngineConfig, FaultPlan, ReplicaKill, Server,
                           ServingCluster, ServingEngine)
from repro.serving.pager import SCRATCH_PAGE, PageAllocator
from repro.serving.prefix_cache import PrefixCache

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - env-dependent
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)
MAXLEN = 96
PS = 16                                  # page size used by every engine here


def _cfg(variant: str) -> ModelConfig:
    # identical to tests/test_paging.py's configs *including the name*: the
    # engine's jitted steps key their compile cache on the (static, frozen)
    # ModelConfig, so reusing the exact value means this module re-uses the
    # paging suite's compiled executables instead of re-JITting every
    # bucket x variant shape under a fresh name (the full tier-1 run has
    # enough compilations in one process without gratuitous duplicates)
    kw = dict(name=f"tp-{variant}", arch_type="dense", num_layers=2,
              d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
              vocab_size=128, dtype="float32", max_seq=512)
    if variant == "gqa":
        kw["num_kv_heads"] = 2
    elif variant == "kv_quant":
        kw.update(num_kv_heads=2, kv_quant=True)
    return ModelConfig(**kw)


CFG = _cfg("full")


@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_cache():
    """By the time this module runs, the tier-1 suite has JITted hundreds
    of executables in one process, and on the single-core CI runner
    XLA:CPU's JIT has been observed to segfault on the next *fresh*
    compilation past that load (the faulthandler stack bottoms out in
    ``backend_compile``).  Dropping the accumulated executables first
    resets the process to this module's standalone compile set, which
    passes; the shared-name configs above keep the recompile bill small."""
    jax.clear_caches()


@pytest.fixture(scope="module")
def params():
    return init_params(KEY, CFG)


def _ecfg(cache=True, **kw):
    kw.setdefault("cache_dtype", "float32")
    kw.setdefault("governor", "defaultnv")
    kw.setdefault("max_batch", 4)
    kw.setdefault("page_size", PS)
    return EngineConfig(max_len=MAXLEN, paged=True, prefix_cache=cache, **kw)


def _engine(cfg, params, cache=True, **kw):
    return ServingEngine(cfg, params=params, ecfg=_ecfg(cache, **kw))


def _reference_tokens(params, cfg, prompt, output_len):
    caches = init_cache(cfg, 1, MAXLEN, dtype=jnp.float32)
    lg, caches, pos = prefill(params, cfg,
                              jnp.asarray(prompt, jnp.int32)[None], caches)
    toks = [int(jnp.argmax(lg[0]))]
    while len(toks) < max(output_len, 2) and pos < MAXLEN - 1:
        lg, caches = decode_step(params, cfg,
                                 jnp.asarray([[toks[-1]]], jnp.int32),
                                 caches, jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    return toks


def _shared_head_burst(cfg, n=6, head_len=32, seed=2, max_tokens=8):
    """n prompts sharing a head_len-token head, mixed greedy + seeded
    sampling — hits must replay both.  Tails keep total length under
    max_len // 2 so the engine's keep-the-tail prompt truncation never
    chops the shared head."""
    rng = np.random.default_rng(seed)
    head = rng.integers(0, cfg.vocab_size, size=head_len)
    prompts = [np.concatenate(
        [head, rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, 12)))])
        for _ in range(n)]
    sps = [SamplingParams(max_tokens=max_tokens, temperature=0.7,
                          seed=100 + i) if i % 2 else
           SamplingParams(max_tokens=max_tokens) for i in range(n)]
    return prompts, sps


def _force_chunk(eng, n=16):
    """Shrink the admission buckets so prompts > n take the chunked path
    (same helper as tests/test_paging.py)."""
    eng.buckets = [b for b in eng.buckets if b <= n] or [n]
    eng.chunk_len = eng.buckets[-1]


def _run_engine(cfg, params, prompts, sps, cache, **kw):
    eng = _engine(cfg, params, cache, **kw)
    srv = Server(eng)
    hs = [srv.submit(p, sp) for p, sp in zip(prompts, sps)]
    rep = srv.run()
    return eng, rep, [h.request.tokens for h in hs]


# -- hit == miss, bit-identical ------------------------------------------------

@pytest.mark.parametrize("variant", ["full", "gqa", "kv_quant"])
def test_hit_matches_miss_token_exact(variant):
    """A shared-prefix burst through a cache-enabled engine emits tokens
    bit-identical to the cache-off run — greedy and seeded rows — and the
    greedy rows also match the scalar one-stream reference."""
    cfg = _cfg(variant)
    params = init_params(KEY, cfg)
    prompts, sps = _shared_head_burst(cfg)
    _, _, cold = _run_engine(cfg, params, prompts, sps, cache=False)
    eng, rep, warm = _run_engine(cfg, params, prompts, sps, cache=True)
    assert warm == cold
    assert rep.completed == len(prompts)
    st = eng.stats()
    assert st["prefix_cache_hits"] > 0
    assert st["prefix_cache_hit_tokens"] >= st["prefix_cache_hits"] * PS
    for p, t, sp in zip(prompts, warm, sps):
        if sp.temperature is None:
            assert t == _reference_tokens(params, cfg, p, sp.max_tokens)


def test_fully_covered_prompt_cow_exact(params):
    """Resubmitting an identical prompt is the copy-on-write case: the
    matched-token cap forces the last cached page to be rewritten at its
    final position, so the hit stream must get a private copy first.  Both
    the page-aligned and mid-page prompt lengths stay token-exact across
    three generations of resubmission, and the cached bits stay pristine."""
    for size in (2 * PS, 2 * PS + 5):        # aligned / mid-page
        rng = np.random.default_rng(size)
        prompt = rng.integers(0, CFG.vocab_size, size=size)
        prompts, sps = [prompt] * 3, [SamplingParams(max_tokens=8)] * 3
        eng, rep, toks = _run_engine(CFG, params, prompts, sps, cache=True)
        ref = _reference_tokens(params, CFG, prompt, 8)
        assert toks == [ref] * 3
        assert rep.completed == 3
        assert eng.stats()["prefix_cache_hits"] >= 1


def test_hit_exact_under_pool_pressure(params):
    """An over-committed pool with the cache competing for pages: reclaim
    (evict unreferenced cached prefixes) and preemption must between them
    drain the burst completely, token-exactly vs the cache-off twin.
    Pressure comes from reserving most of the default pool (the
    fault-injection hook) rather than shrinking ``num_pages``, so the
    buffer shapes — and therefore the compiled executables — are the same
    ones every other test here uses."""
    prompts, sps = _shared_head_burst(CFG, n=4, head_len=PS, seed=3,
                                      max_tokens=16)

    def run(cache):
        eng = _engine(CFG, params, cache)
        _force_chunk(eng)
        eng.pager.reserve(eng.pager.pages_free - 7)   # 7 usable pages
        srv = Server(eng)
        hs = [srv.submit(p, sp) for p, sp in zip(prompts, sps)]
        rep = srv.run()
        return eng, rep, [h.request.tokens for h in hs]

    _, _, cold = run(False)
    eng, rep, warm = run(True)
    assert warm == cold
    assert rep.completed == len(prompts)
    st = eng.stats()
    assert st["preempted"] + st["prefix_cache_evictions"] > 0
    assert eng.pager.pages_used == \
        eng.pager.pages_retained + eng.pager.pages_reserved


def test_cancel_hit_stream_leaves_sharers_exact(params):
    """Cancelling streams that share cached pages mid-flight must not
    disturb the survivors (bit-identical to the cancel-free run) and must
    not leak: after the drain the only pages still held are the cache's,
    and clearing the cache returns the pool to baseline."""
    prompts, sps = _shared_head_burst(CFG, n=9, seed=5, max_tokens=20)

    def run(cancel):
        # small decode blocks keep streams in flight across pumps, so the
        # cancel wave hits admitted sharers mid-decode (and one queued)
        eng = _engine(CFG, params, cache=True, decode_block=4)
        srv = Server(eng)
        hs = [srv.submit(p, sp) for p, sp in zip(prompts, sps)]
        if cancel:
            srv._pump()
            for h in hs[::3]:
                h.cancel()
        srv.run()
        return eng, hs

    eng, hs = run(cancel=True)
    assert all(h.state is RequestState.CANCELLED for h in hs[::3])
    assert any(h.request.tokens for h in hs[::3])   # died mid-decode
    survivors = [h.request.tokens for h in hs
                 if h.state is RequestState.FINISHED]
    _, clean = run(cancel=False)
    clean_toks = [h.request.tokens for i, h in enumerate(clean) if i % 3]
    assert survivors == clean_toks
    assert eng.pager.pages_used == eng.pager.pages_retained
    assert eng.prefix_cache.clear() > 0
    assert eng.pager.pages_used == 0
    assert sorted(eng.free_slots) == list(range(eng.ecfg.max_batch))


# -- disabled-cache identity and config gates ----------------------------------

def test_cache_disabled_is_bare_engine(params):
    """prefix_cache=False must leave the engine bit-for-bit the bare paged
    engine: no cache object, no cache stats keys, nominal prefill work."""
    prompts, sps = _shared_head_burst(CFG, n=4, seed=7)
    eng, rep, toks = _run_engine(CFG, params, prompts, sps, cache=False)
    assert eng.prefix_cache is None
    assert not any(k.startswith("prefix_cache") for k in eng.stats())
    assert rep.completed == len(prompts)
    r = Request(rid=99, arrival=0.0, prompt_len=len(prompts[0]),
                output_len=4)
    r.prompt = np.asarray(prompts[0], np.int32)
    assert eng.effective_prefill_tokens(r) == r.prompt_len
    occ = eng.pager.occupancy()
    assert occ["pages_cached"] == 0 and occ["pages_shared"] == 0


def test_prefix_cache_requires_paged():
    with pytest.raises(ValueError, match="requires paged"):
        EngineConfig(max_len=MAXLEN, paged=False, prefix_cache=True)
    with pytest.raises(ValueError, match="prefix_cache_pages"):
        EngineConfig(max_len=MAXLEN, paged=True, prefix_cache=True,
                     prefix_cache_pages=-1)


def test_effective_prefill_tokens_sees_cached_prefix(params):
    """After a warm run the optimizer-facing prefill work for a sharing
    prompt is the tail only (plus >= 1 token for the first logits)."""
    prompts, sps = _shared_head_burst(CFG, n=3, seed=9)
    eng, _, _ = _run_engine(CFG, params, prompts, sps, cache=True)
    tail = np.concatenate([prompts[0][:2 * PS],
                           np.asarray([1, 2, 3], np.int32)])
    r = Request(rid=42, arrival=0.0, prompt_len=len(tail), output_len=4)
    r.prompt = np.asarray(tail, np.int32)
    eff = eng.effective_prefill_tokens(r)
    assert eff == len(tail) - 2 * PS
    assert eng.prefix_cache.probe(tail) == 2 * PS


# -- cluster: handoff, crash recovery, conservation ----------------------------

# same trick as _cfg: tests/test_cluster.py runs its replicas on "tc-full"
# (identical dimensions), so naming ours the same reuses its compiled
# prefill/decode/handoff executables; the params arrays carry no name
CCFG = dataclasses.replace(CFG, name="tc-full")


def _cluster(params, cache, faults=None, n_decode=2):
    return ServingCluster(CCFG, n_prefill=1, n_decode=n_decode,
                          params=params, ecfg=_ecfg(cache), faults=faults)


def _run_cluster(params, cache, faults=None, ledger=None):
    cl = _cluster(params, cache, faults=faults)
    srv = Server(cl, ledger=ledger)
    prompts, sps = _shared_head_burst(CFG, n=6, seed=11)
    hs = [srv.submit(p, sp) for p, sp in zip(prompts, sps)]
    rep = srv.run()
    return cl, rep, [h.request.tokens for h in hs]


def _prefill_engine(cl):
    return next(r.engine for r in cl.replicas if r.name == "prefill0")


def test_cluster_handoff_hit_exact(params):
    """Prefix-cache hits on the prefill replica survive the paged-KV
    handoff to decode replicas: warm cluster tokens == cold cluster
    tokens, and the prefill plane actually hit."""
    _, crep, cold = _run_cluster(params, cache=False)
    cl, wrep, warm = _run_cluster(params, cache=True)
    assert warm == cold
    assert wrep.completed == crep.completed == 6
    assert wrep.migrated > 0
    assert _prefill_engine(cl).stats()["prefix_cache_hits"] > 0


def test_replica_kill_with_cache_recovers_exact(params):
    """Killing a decode replica mid-run with the cache enabled: victims are
    recomputed from the prompt on survivors (re-hitting the cache on the
    prefill plane) and every stream stays bit-identical to the healthy
    warm run."""
    _, healthy, toks0 = _run_cluster(params, cache=True)
    assert healthy.completed == 6
    plan = FaultPlan([ReplicaKill(at=0.4 * healthy.duration_s,
                                  replica="decode1")])
    cl, rep, toks1 = _run_cluster(params, cache=True, faults=plan)
    assert toks1 == toks0
    assert rep.completed == 6
    assert _prefill_engine(cl).stats()["prefix_cache_hits"] > 0


def test_ledger_conservation_bitwise_with_sharing(params):
    """Shared pages shorten prefill, but the attribution ledger's two-layer
    conservation invariant (per-replica and fleet-wide, bitwise) must hold
    exactly as in the cold world."""
    led = EnergyLedger()
    cl, rep, _ = _run_cluster(params, cache=True, ledger=led)
    assert rep.completed == 6
    summ = verify_conservation(led, rep.replicas)
    assert len(summ) == len(rep.replicas)
    assert _prefill_engine(cl).stats()["prefix_cache_hits"] > 0


# -- PrefixCache unit contract -------------------------------------------------

def _pager(num_pages=32, page_size=4, max_streams=4, per_stream=8):
    return PageAllocator(num_pages=num_pages, page_size=page_size,
                         max_streams=max_streams,
                         max_pages_per_stream=per_stream)


def _seed_cache(pager, tokens, slot=0):
    """Allocate a chain for ``tokens`` on ``slot``, register it fully, and
    retire the stream — the cache alone keeps the pages alive."""
    pc = PrefixCache(pager)
    assert pager.ensure(slot, len(tokens))
    chain = list(pager.chains[slot])
    pc.register(tokens, chain, upto=len(tokens))
    pager.free_chain(slot)
    return pc, chain


def test_digest_chain_divergence():
    """One divergent token invalidates its page and every page after it —
    and registered pages outlive the producing stream."""
    a = _pager()
    toks = np.arange(16, dtype=np.int32)
    pc, chain = _seed_cache(a, toks)
    assert len(pc) == 4 and a.pages_retained == 4
    assert a.pages_used == 4                 # cache grip only

    pages, matched = pc.lookup(toks)
    assert matched == 15                     # capped at len - 1
    assert pages == chain
    early = toks.copy()
    early[2] = 99                            # first page diverges
    assert pc.lookup(early) == ([], 0)
    late = toks.copy()
    late[6] = 99                             # second page diverges
    pages, matched = pc.lookup(late)
    assert pages == chain[:1] and matched == 4
    assert pc.stats()["hits"] == 2 and pc.stats()["misses"] == 1


def test_register_partial_prompt_only_full_pages():
    a = _pager()
    pc = PrefixCache(a)
    toks = np.arange(16, dtype=np.int32)
    assert a.ensure(0, 16)
    chain = list(a.chains[0])
    assert pc.register(toks, chain, upto=10) == 2    # 2 full pages of 4
    assert pc.register(toks, chain, upto=16) == 2    # idempotent extension
    assert len(pc) == 4
    a.free_chain(0)
    pc.clear()
    assert a.pages_used == 0


def test_reclaim_never_evicts_shared_or_interior_pages():
    """Eviction victims are LRU *leaves with no stream refs*: pages a live
    chain shares survive unconditionally, and interior entries survive
    while any descendant does."""
    a = _pager()
    toks = np.arange(16, dtype=np.int32)
    pc, chain = _seed_cache(a, toks)
    a.share_chain(1, chain[:2])              # a live stream adopts 2 pages
    freed = pc.reclaim(10)
    assert freed == 2                        # only the unshared tail pages
    assert len(pc) == 2
    assert all(a.stream_refs(p) == 1 for p in chain[:2])
    assert list(a.chains[1]) == chain[:2]    # live chain untouched
    a.free_chain(1)
    assert pc.reclaim(10) == 2               # now evictable
    assert a.pages_used == 0
    assert pc.stats()["evictions"] == 4


def test_capacity_cap_evicts_lru_before_retaining():
    a = _pager(num_pages=32)
    pc = PrefixCache(a, max_pages=2)
    for i in range(3):
        toks = np.full(8, i, np.int32)
        assert a.ensure(i, 8)
        chain = list(a.chains[i])
        pc.register(toks, chain, upto=8)
        a.free_chain(i)
    assert a.pages_retained <= 2             # cap held via LRU reclaim
    assert pc.evictions > 0
    pc.clear()
    assert a.pages_used == 0


# -- allocator properties under sharing ----------------------------------------

def _check_sharing_invariants(a):
    """Conservation, ref == holders, free-list/table consistency — the
    tests/test_paging.py invariants extended to refcounted sharing."""
    assert a.pages_used + a.pages_free == a.num_pages - 1
    holders = np.zeros(a.num_pages, np.int32)
    for chain in a.chains.values():
        for p in chain:
            holders[p] += 1
    for p in a._retained:
        holders[p] += 1
    for p in range(1, a.num_pages):
        assert a.ref[p] == holders[p], f"page {p}: ref != holders"
        in_free = p in a._free_set
        reserved = p in a._reserved
        assert in_free == (holders[p] == 0 and not reserved)
        if holders[p]:
            assert a.stream_refs(p) == holders[p] - (p in a._retained)
    assert holders[SCRATCH_PAGE] == 0
    for s, chain in a.chains.items():
        assert list(a.table[s, :len(chain)]) == chain
        assert (a.table[s, len(chain):] == SCRATCH_PAGE).all()
    occ = a.occupancy()
    assert occ["pages_cached"] == len(a._retained)
    assert occ["pages_reserved"] == len(a._reserved)
    assert 0.0 <= occ["occupancy_live"] <= occ["occupancy"] <= 1.0


def _sharing_storm(seed):
    rng = np.random.default_rng(seed)
    a = PageAllocator(num_pages=24, page_size=8, max_streams=6,
                      max_pages_per_stream=6)
    cached = []                              # ordered retained-page prefixes

    def prune(page):
        cached[:] = [c for c in cached if page not in c]

    for _ in range(250):
        op = rng.random()
        slot = int(rng.integers(0, 6))
        if op < 0.25:                        # grow (private pages)
            held = len(a.chains.get(slot, [])) * a.page_size
            want = min(held + int(rng.integers(1, 17)),
                       a.max_pages_per_stream * a.page_size)
            a.ensure(slot, want)
        elif op < 0.40:                      # retire a stream
            if a.chains.get(slot):
                a.free_chain(slot)
        elif op < 0.55:                      # cache-register a chain prefix
            live = [c for c in a.chains.values() if c]
            if live:
                chain = live[int(rng.integers(0, len(live)))]
                k = int(rng.integers(1, len(chain) + 1))
                for p in chain[:k]:
                    if p not in a._retained:
                        a.retain(p)
                cached.append(list(chain[:k]))
        elif op < 0.70:                      # hit: share a cached prefix
            free_slots = [s for s in range(6) if not a.chains.get(s)]
            ok = [c for c in cached
                  if all(p in a._retained for p in c)]
            if free_slots and ok:
                c = ok[int(rng.integers(0, len(ok)))]
                s = free_slots[0]
                a.share_chain(s, c)
                a.ensure(s, min(len(c) * a.page_size
                                + int(rng.integers(0, 9)),
                                a.max_pages_per_stream * a.page_size))
        elif op < 0.80:                      # evict one cached page
            if a._retained:
                p = sorted(a._retained)[
                    int(rng.integers(0, len(a._retained)))]
                a.release(p)
                prune(p)
        elif op < 0.90:                      # copy-on-write a shared page
            shared = [(s, i) for s, c in a.chains.items()
                      for i, p in enumerate(c) if a.ref[p] > 1]
            if shared:
                s, i = shared[int(rng.integers(0, len(shared)))]
                a.cow_page(s, i)
        elif op < 0.95:
            a.reserve(int(rng.integers(1, 4)))
        else:
            a.release_reserved()
        _check_sharing_invariants(a)

    for s in list(a.chains):
        a.free_chain(s)
    for p in sorted(a._retained):
        a.release(p)
    a.release_reserved()
    _check_sharing_invariants(a)
    assert a.pages_used == 0 and a.pages_free == a.num_pages - 1


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=0, max_value=2 ** 16 - 1))
    def test_allocator_sharing_storm(seed):
        _sharing_storm(seed)
else:
    @pytest.mark.parametrize("seed", [0, 1, 7, 42, 123, 2024])
    def test_allocator_sharing_storm(seed):
        _sharing_storm(seed)


def test_sharing_api_contract():
    a = _pager()
    assert a.ensure(0, 16)
    chain = list(a.chains[0])
    with pytest.raises(ValueError, match="already holds"):
        a.share_chain(0, chain)
    a.retain(chain[0])
    with pytest.raises(ValueError, match="already retained"):
        a.retain(chain[0])
    with pytest.raises(ValueError, match="not retained"):
        a.release(chain[1])
    free_page = a._free[-1]
    with pytest.raises(ValueError, match="dead page"):
        a.share_chain(1, [free_page])
    # exclusively-held pages are already private: cow is the identity
    assert a.cow_page(0, 1) == chain[1]
    # shared pages get a fresh id and the original keeps its holders
    a.share_chain(1, chain[:2])
    new = a.cow_page(1, 0)
    assert new != chain[0] and a.chains[1][0] == new
    assert a.ref[chain[0]] == 2              # slot 0 + the cache grip
    a.free_chain(0)
    a.free_chain(1)
    a.release(chain[0])
    assert a.pages_used == 0


def test_occupancy_telemetry_counts_shared_and_cached(params):
    """Engine-level occupancy telemetry distinguishes live, shared,
    reserved, and cache-held pages mid-run and after the drain."""
    prompts, sps = _shared_head_burst(CFG, n=6, seed=13)
    eng, _, _ = _run_engine(CFG, params, prompts, sps, cache=True)
    occ = eng.pager.occupancy()
    assert occ["pages_cached"] == eng.pager.pages_retained > 0
    assert occ["pages_evictable"] == occ["pages_cached"]  # streams retired
    assert occ["occupancy_live"] == 0.0      # only cache pages remain
    assert occ["occupancy"] > 0.0
    st = eng.stats()
    for k in ("prefix_cache_hits", "prefix_cache_misses",
              "prefix_cache_evictions", "prefix_cache_shared_pages",
              "prefix_cache_hit_rate", "prefix_cache_entries"):
        assert k in st
    assert st["prefix_cache_hit_rate"] > 0.5
