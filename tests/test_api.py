"""Request-lifecycle serving API tests: the ``serving.api.Server`` front
door (submit -> stream -> cancel) over all three backends, cancellation
resource accounting, and typed-report parity.

* Cancellation: cancelling a queued / mid-chunked-prefill / mid-decode
  stream returns its slot and page chain to baseline, never perturbs the
  surviving streams' tokens (greedy f32: decode rows are independent), and
  is recorded in ``ServingReport``.
* Report parity: the ``ServingReport`` from engine, cluster and simulator
  runs of the same trace agrees field-for-field with the paper's
  ``sim.replay.compute_metrics`` scoring (one definition:
  ``core.report.slo_pass_metrics``) — replacing the old ad-hoc dict-key
  assertions.
* Online scenario (impossible before this API): requests arriving over
  virtual time, tokens streamed incrementally at block granularity, a
  mid-flight cancellation, and per-request SLO attainment in the report.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Request, RequestState, SamplingParams
from repro.core.hardware import A100_SXM4_40G
from repro.models import decode_step, init_cache, init_params, prefill
from repro.models.config import ModelConfig
from repro.serving import (Backend, EngineConfig, Server, ServingCluster,
                           ServingEngine)
from repro.sim import (ReplayConfig, ServingSimulator, build_simulator,
                       compute_metrics)

KEY = jax.random.PRNGKey(0)
MAXLEN = 96


def _cfg(**kw) -> ModelConfig:
    base = dict(name="ta", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                vocab_size=128, dtype="float32", max_seq=512)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    return cfg, init_params(KEY, cfg)


def _engine(cfg, params, **kw):
    kw.setdefault("cache_dtype", "float32")
    kw.setdefault("governor", "defaultnv")
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_len", MAXLEN)
    kw.setdefault("paged", True)
    return ServingEngine(cfg, params=params, ecfg=EngineConfig(**kw))


def _reference_tokens(params, cfg, prompt, output_len):
    caches = init_cache(cfg, 1, MAXLEN, dtype=jnp.float32)
    lg, caches, pos = prefill(params, cfg,
                              jnp.asarray(prompt, jnp.int32)[None], caches)
    toks = [int(jnp.argmax(lg[0]))]
    while len(toks) < max(output_len, 2) and pos < MAXLEN - 1:
        lg, caches = decode_step(params, cfg,
                                 jnp.asarray([[toks[-1]]], jnp.int32),
                                 caches, jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    return toks


def _pool_at_baseline(eng):
    assert eng.pager.pages_used == 0
    assert sorted(eng.free_slots) == list(range(eng.ecfg.max_batch))
    assert not eng.active and not eng.prefilling


# -- Backend protocol conformance ---------------------------------------------

def test_all_backends_satisfy_the_protocol(model):
    cfg, params = model
    assert isinstance(_engine(cfg, params), Backend)
    assert isinstance(ServingCluster(cfg, n_prefill=1, n_decode=1,
                                     params=params,
                                     ecfg=EngineConfig(max_batch=2,
                                                       max_len=MAXLEN)),
                      Backend)
    sim = build_simulator(_cfg(), A100_SXM4_40G,
                          ReplayConfig(governor="defaultnv"))
    assert isinstance(sim, ServingSimulator) and isinstance(sim, Backend)


# -- cancellation --------------------------------------------------------------

def test_cancel_queued_request_is_released_and_reported(model):
    cfg, params = model
    eng = _engine(cfg, params)
    srv = Server(eng)
    rng = np.random.default_rng(0)
    h0 = srv.submit(rng.integers(0, cfg.vocab_size, size=12),
                    SamplingParams(max_tokens=6))
    h1 = srv.submit(rng.integers(0, cfg.vocab_size, size=12),
                    SamplingParams(max_tokens=6))
    assert h1.state == RequestState.QUEUED    # nothing stepped yet
    assert h1.cancel() and not h1.cancel()    # second cancel is a no-op
    rep = srv.run()
    _pool_at_baseline(eng)
    assert rep.completed == 1 and rep.cancelled == 1
    assert h0.state == RequestState.FINISHED
    rows = {r.rid: r for r in rep.requests}
    assert rows[h1.rid].state == RequestState.CANCELLED
    assert rows[h1.rid].tokens_out == 0


def test_cancel_mid_chunked_prefill_frees_slot_and_chain():
    # sliding-window config: the bucket cap is the window (16), so a
    # 37-token prompt admits through chunked prefill and is still
    # mid-chunk after one scheduling round
    cfg = _cfg(name="ta-local", block_pattern=("local", "full"), window=16)
    params = init_params(KEY, cfg)
    eng = _engine(cfg, params)
    srv = Server(eng)
    rng = np.random.default_rng(1)
    h = srv.submit(rng.integers(0, cfg.vocab_size, size=37),
                   SamplingParams(max_tokens=6))
    eng.step(1)
    assert h.state == RequestState.PREFILLING
    assert eng.pager.pages_used > 0
    assert h.cancel()
    _pool_at_baseline(eng)
    rep = srv.run()
    assert rep.cancelled == 1 and rep.completed == 0
    assert not eng.has_work()


def test_cancel_mid_decode_frees_pool_and_pool_is_reusable(model):
    cfg, params = model
    eng = _engine(cfg, params)
    srv = Server(eng)
    rng = np.random.default_rng(2)
    h = srv.submit(rng.integers(0, cfg.vocab_size, size=20),
                   SamplingParams(max_tokens=40))
    for _ in range(3):
        eng.step(1)
    assert h.state == RequestState.DECODING and eng.pager.pages_used > 0
    got_before = h.request.tokens_emitted
    assert h.cancel()
    _pool_at_baseline(eng)
    # tokens produced before the cancel stay readable on the handle
    assert list(h.tokens()) == h.request.tokens
    assert h.request.tokens_emitted == got_before
    # the freed slot/pages serve a new request to completion
    prompt = rng.integers(0, cfg.vocab_size, size=9)
    h2 = srv.submit(prompt, SamplingParams(max_tokens=8))
    rep = srv.run()
    assert h2.request.tokens == _reference_tokens(params, cfg, prompt, 8)
    assert rep.completed == 1 and rep.cancelled == 1
    _pool_at_baseline(eng)


@pytest.mark.parametrize("paged", [True, False])
def test_cancel_never_perturbs_surviving_streams(model, paged):
    """Token equivalence: survivors of a mid-decode cancellation emit
    exactly the tokens of a run without the cancelled stream (and of the
    single-stream reference)."""
    cfg, params = model
    rng = np.random.default_rng(3)
    p_keep = rng.integers(0, cfg.vocab_size, size=19)
    p_cancel = rng.integers(0, cfg.vocab_size, size=8)

    eng = _engine(cfg, params, paged=paged)
    srv = Server(eng)
    h_keep = srv.submit(p_keep, SamplingParams(max_tokens=14))
    h_cancel = srv.submit(p_cancel, SamplingParams(max_tokens=14))
    for _ in range(4):
        eng.step(1)
    assert h_cancel.cancel()
    srv.run()
    assert h_keep.request.tokens == _reference_tokens(params, cfg, p_keep,
                                                      14)
    # control: the same request served with no co-resident stream at all
    solo = Server(_engine(cfg, params, paged=paged))
    hs = solo.submit(p_keep, SamplingParams(max_tokens=14))
    solo.run()
    assert hs.request.tokens == h_keep.request.tokens


def test_cluster_cancel_before_arrival_and_in_flight(model):
    cfg, params = model
    cl = ServingCluster(cfg, n_prefill=1, n_decode=1, params=params,
                        ecfg=EngineConfig(max_batch=4, max_len=MAXLEN,
                                          cache_dtype="float32",
                                          governor="defaultnv"))
    srv = Server(cl)
    rng = np.random.default_rng(4)
    hs = [srv.submit(rng.integers(0, cfg.vocab_size, size=10),
                     SamplingParams(max_tokens=6), arrival=0.01 * i)
          for i in range(4)]
    assert hs[3].cancel()         # still in the future-arrival heap
    rep = srv.run()
    assert rep.completed == 3 and rep.cancelled == 1
    assert hs[3].request.tokens_emitted == 0


# -- report parity -------------------------------------------------------------

def _burst(cfg, n=5, seed=7):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab_size, size=int(rng.integers(6, 30))),
             int(rng.integers(4, 10))) for _ in range(n)]


def test_report_parity_engine_vs_colocated_cluster(model):
    """The same burst through the single engine and a 1-replica colocated
    cluster yields the same typed report (identical plant seed): token
    counts and SLO fields exactly, energies to float tolerance."""
    cfg, params = model
    from repro.sim import PlantModel
    burst = _burst(cfg)

    eng = ServingEngine(cfg, params=params,
                        ecfg=EngineConfig(max_batch=4, max_len=MAXLEN,
                                          paged=True, cache_dtype="float32",
                                          governor="defaultnv"),
                        plant=PlantModel(cfg=cfg, hw=A100_SXM4_40G,
                                         n_chips=1, seed=100))
    cl = ServingCluster(cfg, n_prefill=0, n_decode=0, n_colocated=1,
                        params=params,
                        ecfg=EngineConfig(max_batch=4, max_len=MAXLEN,
                                          cache_dtype="float32",
                                          governor="defaultnv"))
    reps = []
    for backend in (eng, cl):
        srv = Server(backend)
        for prompt, out in burst:
            srv.submit(prompt, SamplingParams(max_tokens=out))
        reps.append(srv.run())
    a, b = reps
    assert a.backend == "engine" and b.backend == "cluster"
    for field in ("n_requests", "completed", "cancelled", "preempted",
                  "prefill_tokens", "decode_tokens", "ttft_pass",
                  "tbt_pass"):
        assert getattr(a, field) == getattr(b, field), field
    assert a.prefill_energy_j == pytest.approx(b.prefill_energy_j)
    assert a.decode_energy_j == pytest.approx(b.decode_energy_j)
    assert a.duration_s == pytest.approx(b.duration_s)
    assert a.p95_tbt_s == pytest.approx(b.p95_tbt_s)
    ra = sorted(a.requests, key=lambda r: r.rid)
    rb = sorted(b.requests, key=lambda r: r.rid)
    for x, y in zip(ra, rb):
        assert (x.state, x.tokens_out, x.ttft_ok, x.tbt_ok) == \
            (y.state, y.tokens_out, y.ttft_ok, y.tbt_ok)


def test_report_parity_simulator_vs_compute_metrics():
    """The simulator's ``report()`` agrees field-for-field with the paper's
    ``compute_metrics`` over the identical run (same plant seeds)."""
    from repro.configs import get_config
    cfg = get_config("qwen2-1.5b")
    rc = ReplayConfig(governor="greenllm")
    rng = np.random.default_rng(11)
    trace = [Request(rid=i, arrival=0.2 * i,
                     prompt_len=int(rng.integers(64, 2000)),
                     output_len=int(rng.integers(8, 40)))
             for i in range(12)]

    res = build_simulator(cfg, A100_SXM4_40G, rc).run(
        [copy.copy(r) for r in trace])
    m = compute_metrics(res, rc.slo)

    sim = build_simulator(cfg, A100_SXM4_40G, rc)
    srv = Server(sim)
    for r in trace:
        srv.submit(r.prompt_len, SamplingParams(max_tokens=r.output_len),
                   arrival=r.arrival, rid=r.rid)
    rep = srv.run()

    assert rep.backend == "simulator"
    assert rep.n_requests == m.n_requests
    assert rep.ttft_pass == pytest.approx(m.ttft_pass)
    assert rep.tbt_pass == pytest.approx(m.tbt_pass)
    assert dict(rep.p90_ttft_s) == pytest.approx(m.p90_ttft)
    assert rep.p95_tbt_s == pytest.approx(m.p95_tbt)
    assert rep.p99_tbt_s == pytest.approx(m.p99_tbt)
    assert rep.prefill_energy_j == pytest.approx(m.prefill_energy_j)
    assert rep.decode_energy_j == pytest.approx(m.decode_energy_j)
    assert rep.total_energy_j == pytest.approx(m.total_energy_j)
    assert rep.throughput_tok_s == pytest.approx(m.throughput_tok_s)


# -- the online scenario (the acceptance demo) ---------------------------------

def test_online_arrivals_streaming_and_mid_flight_cancel(model):
    """Requests arrive over virtual time, tokens stream incrementally (at
    block granularity), one stream is cancelled mid-flight, and the report
    carries per-request SLO attainment — none of which the old
    pre-submit-everything ``run_until_drained`` interface could express."""
    cfg, params = model
    # small decode blocks: tokens stream in bursts of <= 4, so the stream
    # is observably incremental (with the default 64 the whole answer can
    # land in one block)
    eng = _engine(cfg, params, decode_block=4)
    srv = Server(eng)
    rng = np.random.default_rng(5)
    h0 = srv.submit(rng.integers(0, cfg.vocab_size, size=24),
                    SamplingParams(max_tokens=24))
    h1 = srv.submit(rng.integers(0, cfg.vocab_size, size=10),
                    SamplingParams(max_tokens=48), arrival=0.002)
    h2 = srv.submit(rng.integers(0, cfg.vocab_size, size=16),
                    SamplingParams(max_tokens=12), arrival=4.0,
                    deadline=30.0)

    streamed = []
    it = h0.tokens()
    for tok in it:
        streamed.append(tok)
        if len(streamed) == 5:
            break
    # h0 still live; h1 has been admitted behind it on the same clock
    assert not h0.done
    assert h1.state in (RequestState.QUEUED, RequestState.DECODING)
    assert h1.cancel()            # mid-flight cancellation
    streamed.extend(it)           # drain the rest of h0's stream
    assert streamed == h0.request.tokens and len(streamed) == 24

    rep = srv.run()
    assert h2.state == RequestState.FINISHED   # arrived at t=4.0, served
    assert rep.completed == 2 and rep.cancelled == 1
    assert rep.idle_energy_j > 0.0             # waited for h2's arrival
    rows = {r.rid: r for r in rep.requests}
    assert rows[h2.rid].ttft >= 0.0            # never served before arrival
    assert rows[h2.rid].deadline_ok is True
    assert rows[h0.rid].deadline_ok is None    # no deadline given
    assert rows[h1.rid].state == RequestState.CANCELLED
    for r in (h0, h2):
        assert rows[r.rid].ttft_ok in (True, False)
        assert rows[r.rid].tbt_ok in (True, False)
    _pool_at_baseline(eng)


def test_drain_events_block_granularity_and_ordering(model):
    """The observability surface for external consumers: tokens arrive as
    one TokenEvent per stream per decode block (never per token), event
    counts reconstruct the full output, FINISHED comes strictly after the
    stream's final tokens, and a cancel emits a CANCELLED StateEvent."""
    from repro.core import StateEvent, TokenEvent
    cfg, params = model
    eng = _engine(cfg, params)
    eng.submit(Request(rid=0, arrival=0.0, prompt_len=10, output_len=9))
    eng.submit(Request(rid=1, arrival=0.0, prompt_len=6, output_len=30))
    events = []
    for _ in range(3):      # single steps: rid 1 must still be decoding
        eng.step(1)
        events.extend(eng.drain_events())
    assert eng.drain_events() == []         # drained on read
    assert eng.cancel(1)
    events.extend(eng.drain_events())
    while eng.has_work():
        eng.step()          # horizon-sized blocks from here on
        events.extend(eng.drain_events())

    tok = [e for e in events if isinstance(e, TokenEvent) and e.rid == 0]
    # events reconstruct the output exactly, in strictly fewer events than
    # tokens (block granularity: the tail arrives as multi-token blocks)
    assert sum(e.n for e in tok) == 9 and len(tok) < 9
    assert [t for e in tok for t in e.tokens] == eng.requests[0].tokens
    fin = [i for i, e in enumerate(events)
           if isinstance(e, StateEvent) and e.rid == 0
           and e.state is RequestState.FINISHED]
    last_tok = max(i for i, e in enumerate(events)
                   if isinstance(e, TokenEvent) and e.rid == 0)
    assert len(fin) == 1 and fin[0] > last_tok
    assert any(isinstance(e, StateEvent) and e.rid == 1
               and e.state is RequestState.CANCELLED for e in events)
    states = [e.state for e in events
              if isinstance(e, StateEvent) and e.rid == 0]
    assert states[0] is RequestState.DECODING
    assert states[-1] is RequestState.FINISHED


def test_engine_serves_out_of_order_arrivals_without_stalling(model):
    """The engine backend is FIFO by submission order; a later-submitted
    request with an *earlier* arrival must not deadlock the idle jump
    (regression: _advance_idle once targeted min(arrivals) while _admit
    gates on the head, tripping the stall detector)."""
    cfg, params = model
    srv = Server(_engine(cfg, params))
    rng = np.random.default_rng(6)
    h0 = srv.submit(rng.integers(0, cfg.vocab_size, size=8),
                    SamplingParams(max_tokens=4), arrival=10.0)
    h1 = srv.submit(rng.integers(0, cfg.vocab_size, size=8),
                    SamplingParams(max_tokens=4), arrival=5.0)
    rep = srv.run()
    assert rep.completed == 2 and rep.idle_energy_j > 0
    rows = {r.rid: r for r in rep.requests}
    for h in (h0, h1):      # served at/after its own arrival, never before
        assert rows[h.rid].ttft >= 0.0


# -- config / params validation ------------------------------------------------

def test_engine_config_rejects_impossible_combinations():
    with pytest.raises(ValueError, match="divisible by"):
        EngineConfig(max_len=100, paged=True, page_size=16)
    with pytest.raises(ValueError, match="scratch"):
        EngineConfig(max_len=128, paged=True, page_size=16, num_pages=1)
    # undersized pools (< one page per slot) stay legal: pool pressure is
    # handled by preemption + recompute-on-resume, not rejection
    EngineConfig(max_batch=8, max_len=128, paged=True, page_size=16,
                 num_pages=4)
    with pytest.raises(ValueError, match="min_bucket"):
        EngineConfig(max_len=16, min_bucket=16)
    with pytest.raises(ValueError, match="slot_native"):
        EngineConfig(paged=True, slot_native=False)
    with pytest.raises(ValueError, match="max_batch"):
        EngineConfig(max_batch=0)
    with pytest.raises(ValueError, match="decode_block"):
        EngineConfig(decode_block=0)


def test_engine_rejects_min_bucket_above_attention_buffer(model):
    cfg = _cfg(name="ta-local-mb", block_pattern=("local", "full"),
               window=16)
    params = init_params(KEY, cfg)
    with pytest.raises(ValueError, match="attention buffer"):
        ServingEngine(cfg, params=params,
                      ecfg=EngineConfig(max_len=MAXLEN, min_bucket=32))


def test_sampling_params_validation(model):
    cfg, params = model
    with pytest.raises(ValueError, match="max_tokens"):
        SamplingParams(max_tokens=0)
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.5)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=1.5)
    # sampling is per-request now: a greedy-default engine accepts any
    # temperature (the old engine-global temperature-mismatch ValueError is
    # gone) and serves the mixed batch through one submit surface
    srv = Server(_engine(cfg, params))     # greedy-default engine
    srv.submit(np.arange(4) % cfg.vocab_size,
               SamplingParams(max_tokens=4, temperature=0.7, seed=1))
    srv.submit(np.arange(4) % cfg.vocab_size,
               SamplingParams(max_tokens=4, temperature=0.0))
    srv.submit(np.arange(4) % cfg.vocab_size, SamplingParams(max_tokens=4))
    rep = srv.run()
    assert rep.completed == 3
    # the legacy data plane decodes greedily host-side: a sampled request
    # must be rejected loudly, never silently argmaxed
    legacy = Server(ServingEngine(
        cfg, params=params, ecfg=EngineConfig(max_batch=2, max_len=MAXLEN,
                                              governor="defaultnv",
                                              slot_native=False)))
    with pytest.raises(ValueError, match="slot-native"):
        legacy.submit(np.arange(4) % cfg.vocab_size,
                      SamplingParams(max_tokens=4, temperature=0.7))


# -- the on_event observability hook -------------------------------------------

def test_on_event_callback_receives_the_stream(model):
    """``Server(backend, on_event=...)`` pushes every buffered TokenEvent /
    StateEvent through the front door, in order, at block granularity —
    the gap that used to force observers to drive the backend directly."""
    from repro.core import StateEvent, TokenEvent
    cfg, params = model
    events = []
    eng = _engine(cfg, params, decode_block=4)
    srv = Server(eng, on_event=events.append)
    assert eng.events_on is True
    rng = np.random.default_rng(8)
    h0 = srv.submit(rng.integers(0, cfg.vocab_size, size=10),
                    SamplingParams(max_tokens=9))
    h1 = srv.submit(rng.integers(0, cfg.vocab_size, size=6),
                    SamplingParams(max_tokens=5, temperature=0.8, seed=3))
    rep = srv.run()
    assert rep.completed == 2
    for h in (h0, h1):
        tok = [e for e in events
               if isinstance(e, TokenEvent) and e.rid == h.rid]
        # block granularity: fewer events than tokens, reconstructing the
        # output exactly
        assert [t for e in tok for t in e.tokens] == h.request.tokens
        assert len(tok) < len(h.request.tokens)
        states = [e.state for e in events
                  if isinstance(e, StateEvent) and e.rid == h.rid]
        assert states[-1] is RequestState.FINISHED
    assert not eng._events               # everything was delivered


def test_no_listener_skips_event_buffering(model):
    """Without an on_event callback the Server turns backend buffering off:
    nothing accumulates even while tokens stream through the handles."""
    cfg, params = model
    eng = _engine(cfg, params)
    srv = Server(eng)
    assert eng.events_on is False
    h = srv.submit(np.arange(8) % cfg.vocab_size,
                   SamplingParams(max_tokens=6))
    for _ in range(3):
        eng.step(1)
        assert eng._events == []         # buffering skipped at the source
    rep = srv.run()
    assert rep.completed == 1 and h.request.tokens_emitted == 6
    # a backend driven directly (no Server) still buffers by default
    eng2 = _engine(cfg, params)
    assert eng2.events_on is True
    eng2.submit(Request(rid=0, arrival=0.0, prompt_len=8, output_len=4))
    eng2.step(1)
    assert eng2.drain_events()
