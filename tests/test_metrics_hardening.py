"""Adversarial regression tests for the Prometheus exposition layer
(ROADMAP PR 8 satellite): hostile label values must round-trip through
``render_prometheus`` -> ``parse_prometheus`` key-for-key against
``MetricsRegistry.flat()``, non-finite values must render as the legal
exposition tokens, malformed scrapes must be *rejected* (not silently
mis-keyed), and the shared bucket-quantile helper must interpolate the
way both the alert engine and the dashboard assume it does.
"""
import math

import pytest

from repro.core import MetricsRegistry, quantile_from_buckets
from repro.core.metrics import parse_prometheus

HOSTILE = [
    'plain',
    'sp ace and\ttab',
    'quo"te',
    'back\\slash',
    'new\nline',
    'comma,brace}{equals=',
    '\\" tricky \\\\',
    '',                                   # empty label value is legal
]


def test_hostile_labels_round_trip():
    reg = MetricsRegistry()
    g = reg.gauge("hostile_gauge", "adversarial labels", ["who", "what"])
    for i, v in enumerate(HOSTILE):
        g.set(float(i), who=v, what=HOSTILE[-1 - i])
    text = reg.render_prometheus()
    parsed = parse_prometheus(text)
    assert parsed == reg.flat()
    assert len([k for k in parsed if k.startswith("hostile_gauge")]) \
        == len(HOSTILE)


def test_nonfinite_values_render_and_parse():
    reg = MetricsRegistry()
    g = reg.gauge("weird_vals", "", ["k"])
    g.set(float("nan"), k="nan")
    g.set(math.inf, k="pinf")
    g.set(-math.inf, k="ninf")
    text = reg.render_prometheus()
    assert 'weird_vals{k="nan"} NaN' in text
    assert 'weird_vals{k="pinf"} +Inf' in text
    assert 'weird_vals{k="ninf"} -Inf' in text
    parsed = parse_prometheus(text)
    assert math.isnan(parsed['weird_vals{k="nan"}'])
    assert parsed['weird_vals{k="pinf"}'] == math.inf
    assert parsed['weird_vals{k="ninf"}'] == -math.inf


def test_histogram_exposition_round_trip():
    reg = MetricsRegistry()
    h = reg.histogram("rt_seconds", "", ["replica"],
                      buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v, replica='r"0')
    parsed = parse_prometheus(reg.render_prometheus())
    assert parsed == reg.flat()
    # cumulative le buckets, +Inf == _count
    assert parsed['rt_seconds_bucket{replica="r\\"0",le="+Inf"}'] \
        == parsed['rt_seconds_count{replica="r\\"0"}'] == 4


@pytest.mark.parametrize("bad", [
    'm{k="unterminated} 1',               # quote never closed
    'm{k="bad\\escape"} 1',               # \e is not a valid escape
    'm{k="v"}',                           # no value field
    'm{9k="v"} 1',                        # label name starts with a digit
    'm{k="a" j="b"} 1',                   # missing comma between labels
    'm{k="v" 1',                          # missing closing brace
    '{k="v"} 1',                          # empty metric name
    'm{k="v"} notanumber',                # unparseable value
])
def test_malformed_lines_are_rejected(bad):
    with pytest.raises(ValueError):
        parse_prometheus(bad + "\n")


def test_parse_ignores_comments_and_timestamps():
    text = "# HELP m help\n# TYPE m gauge\nm 2.5 1700000000\n\n"
    assert parse_prometheus(text) == {"m": 2.5}


# -- bucket quantiles (shared by alerts + dashboard) ---------------------------


def test_quantile_interpolation():
    # 10 obs uniform in (0, 0.1], 10 in (0.1, 1.0]
    pairs = [(0.1, 10.0), (1.0, 20.0), (math.inf, 20.0)]
    assert quantile_from_buckets(pairs, 0.5) == pytest.approx(0.1)
    # rank 15 of 20 -> halfway through the (0.1, 1.0] bucket
    assert quantile_from_buckets(pairs, 0.75) == pytest.approx(0.55)
    # everything below the first bound interpolates from zero
    assert 0.0 < quantile_from_buckets(pairs, 0.25) <= 0.1


def test_quantile_inf_clamps_to_highest_finite_bound():
    pairs = [(0.1, 5.0), (math.inf, 10.0)]
    assert quantile_from_buckets(pairs, 0.99) == pytest.approx(0.1)


def test_quantile_edge_cases():
    assert math.isnan(quantile_from_buckets([], 0.5))
    assert math.isnan(quantile_from_buckets([(0.1, 0.0),
                                             (math.inf, 0.0)], 0.5))
    with pytest.raises(ValueError):
        quantile_from_buckets([(0.1, 1.0)], 1.5)


def test_histogram_quantile_convenience():
    reg = MetricsRegistry()
    h = reg.histogram("q_seconds", "", ["r"], buckets=(0.1, 1.0))
    assert math.isnan(h.quantile(0.95, r="a"))       # no child yet
    for _ in range(10):
        h.observe(0.05, r="a")
    q = h.quantile(0.95, r="a")
    assert 0.0 < q <= 0.1
