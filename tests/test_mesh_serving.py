"""Mesh-sharded serving equivalence: the PR 10 tentpole harness.

The contract under test is *bit-exactness*: the same serving trace on a
``(data, model)`` device mesh — per-slot state, cache rows, the page table
and the paged KV pool sharded along ``data``; parameters storage-sharded and
gathered to replicated at kernel entry — produces tokens, ServingReport
energy/SLO floats, and host-drain counts identical to the single-device
engine, bit for bit.  Not allclose: batch rows are independent and the
parameter gather is pure data movement, so nothing may drift.

Multi-device meshes need more than one XLA device, which on CPU requires
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* jax import —
so those runs happen in subprocess workers (``tests/mesh_runner.py``), one
per mesh shape, each running the full scenario set: dense and MoE engines
with prefix-cache hits, a mid-run cancel, pool-pressure preemption, mixed
greedy/seeded sampling; a disaggregated cluster with prefill->decode
handoffs and a replica kill.  The in-process tests cover the mesh=(1,1)
degenerate case and the config/handoff validation surface.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MESHES = ["1,1", "8,1", "2,4", "4,2"]


def _run_worker(mesh: str, scenarios: str = "dense,moe,cluster") -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "mesh_runner.py"),
         "--mesh", mesh, "--scenarios", scenarios],
        capture_output=True, text=True, timeout=1200, env=env, cwd=ROOT)
    assert proc.returncode == 0, \
        f"mesh worker {mesh} failed:\n{proc.stderr[-4000:]}"
    return json.loads(proc.stdout.splitlines()[-1])


@pytest.fixture(scope="module")
def digests():
    """One worker per mesh shape plus the unsharded anchor, all under the
    same forced-8-device topology, all scenarios per worker."""
    return {m: _run_worker(m) for m in ["none"] + MESHES}


@pytest.mark.slow
@pytest.mark.parametrize("mesh", MESHES)
@pytest.mark.parametrize("scenario", ["dense", "moe", "cluster"])
def test_mesh_bit_identical_to_single_device(digests, mesh, scenario):
    """Tokens, report floats, drains, prefix hits, cancelled rid — the whole
    digest — must match the unsharded baseline bitwise on every mesh shape,
    from the degenerate (1,1) to the full 8-device layouts."""
    base, got = digests["none"][scenario], digests[mesh][scenario]
    assert got["tokens"] == base["tokens"], f"{scenario} tokens on {mesh}"
    assert got == base, f"{scenario} digest diverged on mesh {mesh}"


@pytest.mark.slow
def test_scenarios_exercise_the_hard_paths(digests):
    """The equivalence above is only as strong as the trace: assert the
    scenarios really hit preemption, cancel, prefix hits, and migration."""
    d = digests["none"]
    assert d["dense"]["report"]["preempted"] > 0
    assert d["dense"]["report"]["cancelled"] == 1
    assert d["dense"]["cancelled_rid"] is not None
    assert d["dense"]["prefix_hits"] > 0
    assert d["dense"]["prefix_hit_tokens"] > 0
    assert d["cluster"]["report"]["migrated"] > 0
    assert d["cluster"]["faulted_report"]["completed"] == \
        d["cluster"]["report"]["completed"]
    for scen in ("dense", "moe", "cluster"):
        assert d[scen]["host_drains"] > 0


@pytest.mark.slow
def test_mesh_compile_budget(digests):
    """Compile-count regression on the forced-8-device meshes: sharded
    operands must hit the same jit cache entries block after block (a pin
    that drifts to a different sharding forces a recompile), so every
    multi-device mesh's kernel cache sizes equal the unsharded baseline's
    exactly.  The degenerate (1,1) mesh pays a handful of warm-up
    recompiles — XLA normalizes 1-device NamedSharding outputs back to
    plain single-device placement, so second calls see different input
    shardings — and is held to the bucket-arithmetic bound only: x2
    (sampled/greedy) x the distinct model configs the worker ran
    (dense, moe, cluster-dense)."""
    base = digests["none"]["compiles"]
    for m in MESHES:
        if m == "1,1":
            continue
        assert digests[m]["compiles"] == base, f"compile drift on mesh {m}"
    n_cfgs = 3                       # mesh-dense, mesh-moe, cluster's dense
    dense = digests["none"]["dense"]
    buckets = len(dense["buckets"])
    ctx = len(dense["ctx_buckets"])
    kblocks = len(dense["k_blocks"])
    for m in ["none"] + MESHES:
        got = digests[m]["compiles"]
        assert got["_prefill_kernel"] <= buckets * 2 * n_cfgs, m
        assert got["_chunk_prefill_kernel"] <= buckets * ctx * 2 * n_cfgs, m
        assert got["_paged_decode_block_kernel"] \
            <= ctx * kblocks * 2 * n_cfgs, m
        assert got["_decode_block_kernel"] <= ctx * kblocks * 2 * n_cfgs, m


# -- in-process: the degenerate mesh and the validation surface ---------------

def _cfg(**kw):
    from repro.models.config import ModelConfig
    base = dict(name="tm", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                vocab_size=128, dtype="float32", max_seq=512)
    base.update(kw)
    return ModelConfig(**base)


def _trace(mesh):
    from repro.core import Request, SamplingParams
    from repro.serving import EngineConfig, Server, ServingEngine
    cfg = _cfg()
    ecfg = EngineConfig(max_batch=4, max_len=96, paged=True,
                        prefix_cache=True, cache_dtype="float32",
                        governor="defaultnv", mesh=mesh)
    eng = ServingEngine(cfg, ecfg=ecfg, seed=0)
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(6):
        sp = SamplingParams(max_tokens=8, temperature=0.6, seed=50 + i) \
            if i % 2 else SamplingParams(max_tokens=8)
        r = Request(rid=i, arrival=0.0, prompt_len=9 + i, output_len=8,
                    sampling=sp)
        eng.submit(r, rng.integers(1, cfg.vocab_size - 1, size=9 + i))
        reqs.append(r)
    Server(eng).run()
    rep = eng.report()
    return ([list(r.tokens) for r in reqs],
            rep.prefill_energy_j, rep.decode_energy_j, rep.duration_s,
            rep.ttft_pass, rep.tbt_pass, eng._host_drains)


def test_one_by_one_mesh_equals_unsharded():
    """mesh=(1,1) must be the identity: same tokens, same energy floats,
    same drain count as mesh=None — in one process, no forced devices."""
    assert _trace(None) == _trace((1, 1))


def test_engine_config_rejects_bad_mesh():
    from repro.serving import EngineConfig
    with pytest.raises(ValueError, match="pair"):
        EngineConfig(mesh=(2,))
    with pytest.raises(ValueError, match=">= 1"):
        EngineConfig(mesh=(0, 2))
    with pytest.raises(ValueError, match="max_batch"):
        EngineConfig(mesh=(3, 1), max_batch=8)
    with pytest.raises(ValueError, match="num_pages"):
        EngineConfig(mesh=(2, 1), paged=True, num_pages=7)
    with pytest.raises(ValueError, match="slot-native"):
        EngineConfig(mesh=(1, 1), slot_native=False)
    assert EngineConfig(mesh=[4, "2"]).mesh == (4, 2)  # normalized


def test_engine_rejects_indivisible_model_axes():
    """Model-dependent divisibility fails at construction with an actionable
    error, not deep inside XLA — raised before any device is touched, so a
    1-device process can cover tp=2."""
    from repro.serving import EngineConfig, ServingEngine
    with pytest.raises(ValueError, match="num_heads"):
        ServingEngine(_cfg(num_heads=3, num_kv_heads=3),
                      ecfg=EngineConfig(mesh=(1, 2), max_len=96))
    with pytest.raises(ValueError, match="num_experts"):
        ServingEngine(
            _cfg(arch_type="moe", num_experts=3, experts_per_token=2),
            ecfg=EngineConfig(mesh=(1, 2), max_len=96))


def test_cross_mesh_handoff_rejected():
    """An adopter whose mesh shape differs from the exporter's must refuse
    the stream outright — same contract as cfg_name/page_size mismatches."""
    import dataclasses
    from repro.core import Request
    from repro.models import init_params
    import jax
    from repro.serving import EngineConfig, ServingEngine
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ecfg = EngineConfig(max_batch=4, max_len=96, paged=True,
                        cache_dtype="float32", governor="defaultnv")
    A = ServingEngine(cfg, params=params, ecfg=ecfg)
    B = ServingEngine(cfg, params=params,
                      ecfg=dataclasses.replace(ecfg, mesh=(1, 1)))
    r = Request(rid=0, arrival=0.0, prompt_len=9, output_len=6)
    A.submit(r, np.arange(1, 10))
    A.step(1)
    slot = next(iter(A.active))
    ho = A.export_stream(slot)
    assert ho.mesh_shape is None
    with pytest.raises(AssertionError, match="cross-mesh handoff"):
        B.import_stream(ho)
    # and the matching shape is accepted: same-mesh adoption still works
    C = ServingEngine(cfg, params=params, ecfg=ecfg)
    assert C.import_stream(ho)


def test_build_serving_decode_lowers():
    """The dry-run builder mirrors the engine's sharded paged-decode step:
    it must lower (dense and MoE) with the serving param/cache shardings
    attached, without constructing an engine."""
    import jax
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.specs import build_serving_decode
    mesh = make_serving_mesh(1, 1)
    for cfg in (_cfg(), _cfg(name="tm-moe", arch_type="moe", num_kv_heads=2,
                          num_experts=4, experts_per_token=2)):
        b = build_serving_decode(cfg, mesh, max_batch=4, max_len=64,
                                 page_size=16)
        jax.jit(b["fn"], in_shardings=b["in_shardings"],
                out_shardings=b["out_shardings"],
                donate_argnums=b["donate_argnums"]).lower(*b["args"])
        assert b["meta"]["pool_pages"] > 0
