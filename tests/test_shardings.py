"""Sharding-rule unit tests + a subprocess mini-mesh lowering test.

The subprocess is needed because XLA locks the host device count at first
jax init; the main pytest process must keep seeing 1 CPU device.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_sanitize_spec_drops_nondivisible():
    from repro.launch.shardings import sanitize_spec
    mesh = _FakeMesh({"data": 16, "model": 16})
    assert sanitize_spec(P("model", None), (151936, 64), mesh) == P("model", None)
    assert sanitize_spec(P("model", None), (50280, 64), mesh) == P(None, None)
    assert sanitize_spec(P(("data", "model"), None), (512, 8), mesh) \
        == P(("data", "model"), None)
    assert sanitize_spec(P(("data", "model"), None), (128, 8), mesh) == P(None, None)
    assert sanitize_spec(P(None, "model"), (4, 12), mesh) == P(None, None)


def test_batch_axes_for():
    from repro.launch.shardings import batch_axes_for
    mesh2 = _FakeMesh({"data": 16, "model": 16})
    mesh3 = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert batch_axes_for(mesh2, 256) == ("data",)
    assert batch_axes_for(mesh3, 256) == ("pod", "data")
    assert batch_axes_for(mesh3, 16) == ("data",)
    assert batch_axes_for(mesh3, 1) == ()


@pytest.mark.slow
def test_mini_mesh_lowering_subprocess():
    """Lower train + decode for a reduced arch on an 8-device host mesh."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, dataclasses
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.launch import shardings as SH
        from repro.launch.specs import InputShape, build_step
        # version-compat mesh construction (axis_types only on newer jax)
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(model=4, data=2)
        from repro.launch.specs import build_train
        failures = []
        # FSDP strategy + int8 KV variants also lower
        try:
            cfg = get_config("gemma2-9b").smoke()
            built = build_train(cfg, InputShape("t", "train", 64, 8), mesh,
                                strategy="fsdp")
            jf = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                         out_shardings=built["out_shardings"],
                         donate_argnums=built["donate_argnums"])
            with mesh:
                jf.lower(*built["args"]).compile()
        except Exception as e:
            failures.append(("gemma2-fsdp", "train", repr(e)[:200]))
        for arch in ("qwen2-1.5b", "mixtral-8x7b", "mamba2-370m"):
            cfg = get_config(arch).smoke()
            if arch == "qwen2-1.5b":
                cfg = cfg.replace(kv_quant=True)
            for shape in (InputShape("t", "train", 64, 8),
                          InputShape("d", "decode", 128, 8)):
                try:
                    built = build_step(cfg, shape, mesh)
                    jf = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                                 out_shardings=built["out_shardings"],
                                 donate_argnums=built["donate_argnums"])
                    with mesh:
                        jf.lower(*built["args"]).compile()
                except Exception as e:
                    failures.append((arch, shape.kind, repr(e)[:200]))
        assert not failures, failures
        print("MINI-MESH-OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=600)
    assert r.returncode == 0 and "MINI-MESH-OK" in r.stdout, r.stderr[-2000:]


# -- serving spec properties (PR 10) ------------------------------------------
#
# Random ModelConfigs x mesh shapes, three invariants:
#   1. serving_param_specs partitions only divisible axes (never a dim an
#      axis set doesn't divide);
#   2. placing params with those specs and gathering back is the identity,
#      bit for bit (storage sharding is pure data movement);
#   3. on a divisible 'model' axis, each MoE expert's weights land on exactly
#      one model shard (the expert axis is the only sharded axis of an
#      expert leaf).
#
# The suite runs twice: hypothesis-driven when the optional dep is present
# (requirements-dev.txt convention), and a fixed-seed sweep that always runs.

def _case(seed: int):
    """Deterministic (cfg, fake-mesh) pair from a seed."""
    import numpy as np
    from repro.models.config import ModelConfig
    rng = np.random.RandomState(seed)
    heads = int(rng.choice([2, 3, 4]))
    kv = heads if heads == 3 else int(rng.choice([1, 2, heads]))
    moe = bool(rng.randint(2))
    kw = dict(name=f"p{seed}", arch_type="moe" if moe else "dense",
              num_layers=2, d_model=int(rng.choice([32, 48, 64])),
              num_heads=heads, num_kv_heads=kv, head_dim=16,
              d_ff=int(rng.choice([96, 128])),
              vocab_size=int(rng.choice([100, 128, 160])),
              dtype="float32", max_seq=256)
    if moe:
        kw.update(num_experts=int(rng.choice([2, 3, 4])),
                  experts_per_token=2)
    cfg = ModelConfig(**kw)
    dp = int(rng.choice([1, 2, 3, 4, 8]))
    tp = int(rng.choice([1, 2, 3, 4]))
    return cfg, _FakeMesh({"data": dp, "model": tp})


def _axis_size(mesh, entry):
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _check_spec_case(seed: int):
    import jax
    from repro.launch.shardings import serving_param_specs
    from repro.models.moe import is_expert_leaf
    cfg, mesh = _case(seed)
    specs, shapes = serving_param_specs(cfg, mesh)
    tp = mesh.shape["model"]
    expert_ok = cfg.is_moe and tp > 1 and cfg.num_experts % tp == 0

    def check(path, spec, shape):
        dims = shape.shape
        # (1) only divisible axes are ever assigned
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            size = _axis_size(mesh, entry)
            assert dims[i] % size == 0, (seed, path, spec, dims)
        # (3) expert leaves: expert axis on 'model', nothing else sharded —
        # whole experts per shard, each expert on exactly one shard
        if is_expert_leaf(cfg, path, dims):
            entries = list(spec) + [None] * (len(dims) - len(spec))
            if expert_ok:
                assert entries[1] == "model", (seed, path, spec)
                assert all(e is None for i, e in enumerate(entries)
                           if i != 1), (seed, path, spec)

    jax.tree_util.tree_map_with_path(
        check, specs, shapes,
        is_leaf=lambda x: isinstance(x, P))


def test_serving_spec_properties_seeded():
    """Fixed-seed sweep of the spec properties (always runs)."""
    for seed in range(24):
        _check_spec_case(seed)


def test_serving_spec_properties_hypothesis():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="optional test dep (see requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.integers(min_value=0, max_value=50_000))
    def run(seed):
        _check_spec_case(seed)

    run()


@pytest.mark.slow
def test_serving_param_roundtrip_subprocess():
    """(2) device_put with serving specs + gather back == identity, bitwise,
    for dense and MoE params and a paged serving cache tree — on a real
    8-device (2,4) mesh (subprocess: the forced device count must be set
    before jax init)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_serving_mesh
        from repro.launch.shardings import (named, serving_param_specs,
                                            shard_serving_caches)
        from repro.models import init_params, init_cache
        from repro.models.config import ModelConfig
        mesh = make_serving_mesh(2, 4)
        dense = ModelConfig(name="rt-d", arch_type="dense", num_layers=2,
                            d_model=64, num_heads=4, num_kv_heads=4,
                            head_dim=16, d_ff=128, vocab_size=128,
                            dtype="float32", max_seq=256)
        moe = ModelConfig(name="rt-m", arch_type="moe", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=128,
                          num_experts=4, experts_per_token=2,
                          dtype="float32", max_seq=256)
        for cfg in (dense, moe):
            params = init_params(jax.random.PRNGKey(0), cfg)
            host = jax.tree.map(np.asarray, params)
            specs, _ = serving_param_specs(cfg, mesh)
            placed = jax.device_put(params, named(mesh, specs))
            back = jax.tree.map(np.asarray, jax.device_get(placed))
            eq = jax.tree.map(np.array_equal, host, back)
            assert all(jax.tree.leaves(eq)), cfg.name
            caches = init_cache(cfg, 8, 128, dtype=jnp.float32,
                                paged_pool=(32, 16))
            chost = jax.tree.map(np.asarray, caches)
            cback = jax.tree.map(
                np.asarray,
                jax.device_get(shard_serving_caches(caches, mesh)))
            ceq = jax.tree.map(np.array_equal, chost, cback)
            assert all(jax.tree.leaves(ceq)), cfg.name
        print("ROUNDTRIP-OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=600)
    assert r.returncode == 0 and "ROUNDTRIP-OK" in r.stdout, r.stderr[-2000:]
