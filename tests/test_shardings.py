"""Sharding-rule unit tests + a subprocess mini-mesh lowering test.

The subprocess is needed because XLA locks the host device count at first
jax init; the main pytest process must keep seeing 1 CPU device.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_sanitize_spec_drops_nondivisible():
    from repro.launch.shardings import sanitize_spec
    mesh = _FakeMesh({"data": 16, "model": 16})
    assert sanitize_spec(P("model", None), (151936, 64), mesh) == P("model", None)
    assert sanitize_spec(P("model", None), (50280, 64), mesh) == P(None, None)
    assert sanitize_spec(P(("data", "model"), None), (512, 8), mesh) \
        == P(("data", "model"), None)
    assert sanitize_spec(P(("data", "model"), None), (128, 8), mesh) == P(None, None)
    assert sanitize_spec(P(None, "model"), (4, 12), mesh) == P(None, None)


def test_batch_axes_for():
    from repro.launch.shardings import batch_axes_for
    mesh2 = _FakeMesh({"data": 16, "model": 16})
    mesh3 = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert batch_axes_for(mesh2, 256) == ("data",)
    assert batch_axes_for(mesh3, 256) == ("pod", "data")
    assert batch_axes_for(mesh3, 16) == ("data",)
    assert batch_axes_for(mesh3, 1) == ()


@pytest.mark.slow
def test_mini_mesh_lowering_subprocess():
    """Lower train + decode for a reduced arch on an 8-device host mesh."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, dataclasses
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.launch import shardings as SH
        from repro.launch.specs import InputShape, build_step
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             devices=jax.devices()[:8],
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        from repro.launch.specs import build_train
        failures = []
        # FSDP strategy + int8 KV variants also lower
        try:
            cfg = get_config("gemma2-9b").smoke()
            built = build_train(cfg, InputShape("t", "train", 64, 8), mesh,
                                strategy="fsdp")
            jf = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                         out_shardings=built["out_shardings"],
                         donate_argnums=built["donate_argnums"])
            with mesh:
                jf.lower(*built["args"]).compile()
        except Exception as e:
            failures.append(("gemma2-fsdp", "train", repr(e)[:200]))
        for arch in ("qwen2-1.5b", "mixtral-8x7b", "mamba2-370m"):
            cfg = get_config(arch).smoke()
            if arch == "qwen2-1.5b":
                cfg = cfg.replace(kv_quant=True)
            for shape in (InputShape("t", "train", 64, 8),
                          InputShape("d", "decode", 128, 8)):
                try:
                    built = build_step(cfg, shape, mesh)
                    jf = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                                 out_shardings=built["out_shardings"],
                                 donate_argnums=built["donate_argnums"])
                    with mesh:
                        jf.lower(*built["args"]).compile()
                except Exception as e:
                    failures.append((arch, shape.kind, repr(e)[:200]))
        assert not failures, failures
        print("MINI-MESH-OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=600)
    assert r.returncode == 0 and "MINI-MESH-OK" in r.stdout, r.stderr[-2000:]
