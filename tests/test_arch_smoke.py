"""Deliverable (f): per-architecture smoke tests.

Each assigned architecture is instantiated as a REDUCED variant of the same
family (2 layers / pattern length, d_model <= 512, <= 4 experts) and runs a
forward + one train step on CPU, asserting output shapes and no NaNs.  The
FULL configs are exercised only via the dry-run (ShapeDtypeStructs).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, ASSIGNED_ARCHS, get_config
from repro.models import (init_params, forward_train, loss_fn, init_cache,
                          prefill, decode_step)
from repro.training import AdamWConfig, make_train_step, init_train_state


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch, key):
    cfg = get_config(arch).smoke()
    assert cfg.num_layers <= max(2, len(cfg.block_pattern))
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = init_params(key, cfg)
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pe = (jax.random.normal(key, (B, cfg.num_prefix_embeds, cfg.d_model),
                            jnp.bfloat16) if cfg.num_prefix_embeds else None)
    logits, aux = forward_train(params, cfg, tokens, pe)
    S_total = S + cfg.num_prefix_embeds
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, key):
    cfg = get_config(arch).smoke()
    state = init_train_state(key, cfg)
    step = make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=10),
                           __import__("repro.models", fromlist=["NOSHARD"]).NOSHARD,
                           num_microbatches=1)
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.num_prefix_embeds:
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.num_prefix_embeds, cfg.d_model), jnp.bfloat16)
    state2, metrics = jax.jit(step)(state, batch)
    assert jnp.isfinite(metrics["loss"]), metrics
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually changed
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(state2["params"])[0]
    assert not jnp.allclose(d0.astype(jnp.float32), d1.astype(jnp.float32))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_serve_path(arch, key):
    """prefill -> teacher-forced decode matches full forward (per-arch)."""
    cfg = get_config(arch).smoke().replace(dtype="float32")
    params = init_params(key, cfg)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pe = (jax.random.normal(key, (B, cfg.num_prefix_embeds, cfg.d_model))
          if cfg.num_prefix_embeds else None)
    logits, _ = forward_train(params, cfg, tokens, pe, remat=False)
    caches = init_cache(cfg, B, 64, dtype=jnp.float32)
    lg, caches, pos = prefill(params, cfg, tokens[:, :S - 4], caches, pe)
    assert lg.shape == (B, cfg.vocab_size)
    outs = []
    for i in range(4):
        lg2, caches = decode_step(params, cfg, tokens[:, S - 4 + i:S - 3 + i],
                                  caches, pos + i)
        outs.append(lg2)
    dec = jnp.stack(outs, axis=1)
    want = logits[:, -4:]
    denom = float(jnp.max(jnp.abs(want))) + 1e-9
    rel = float(jnp.max(jnp.abs(want - dec))) / denom
    assert rel < 2e-4, f"{arch}: decode path diverges from forward ({rel})"


def test_assigned_arch_configs_exact():
    """The 10 assigned configs match the assignment table exactly."""
    want = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }
    for arch, (L, d, H, kv, ff, V) in want.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == H, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == V, arch
    moe = get_config("qwen3-moe-30b-a3b")
    assert (moe.num_experts, moe.experts_per_token) == (128, 8)
    mix = get_config("mixtral-8x7b")
    assert (mix.num_experts, mix.experts_per_token) == (8, 2)
    assert mix.window == 4096
    ssm = get_config("mamba2-370m")
    assert ssm.ssm_state == 128
    rg = get_config("recurrentgemma-9b")
    assert rg.block_pattern == ("rglru", "rglru", "local")
    assert len(ASSIGNED_ARCHS) == 10
