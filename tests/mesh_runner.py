"""Subprocess worker for tests/test_mesh_serving.py.

Runs the same serving trace on one mesh shape (or unsharded) and prints a
JSON digest — token sequences per request, ServingReport energy/SLO fields,
host-drain and compile counters — to stdout.  The parent test launches one
worker per mesh under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
and compares digests bitwise: sharded serving must be indistinguishable from
single-device serving, down to the last float.

Runs standalone too (the CI mesh-smoke job calls it directly)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python tests/mesh_runner.py --mesh 2,4
"""
import argparse
import json
import sys


def _dense_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="mesh-dense", arch_type="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
                       d_ff=128, vocab_size=128, dtype="float32", max_seq=512)


def _moe_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="mesh-moe", arch_type="moe", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                       d_ff=128, vocab_size=128, num_experts=4,
                       experts_per_token=2, dtype="float32", max_seq=512)


def _requests(n, vocab, seed=3, out_len=10, dup_every=3):
    """Mixed greedy / seeded-sampled requests; every ``dup_every``-th prompt
    repeats an earlier one so the prefix cache takes real hits."""
    import numpy as np
    from repro.core import Request, SamplingParams
    rng = np.random.default_rng(seed)
    base = [rng.integers(1, vocab - 1, size=int(rng.integers(9, 22)))
            for _ in range(dup_every)]
    prompts, reqs = [], []
    for i in range(n):
        prompts.append(base[i % dup_every])
        sp = SamplingParams(max_tokens=out_len, temperature=0.7,
                            seed=100 + i) if i % 2 else \
            SamplingParams(max_tokens=out_len)
        reqs.append(Request(rid=i, arrival=0.0, prompt_len=len(prompts[-1]),
                            output_len=out_len, sampling=sp))
    return prompts, reqs


def _report_digest(rep):
    return {
        "completed": rep.completed, "cancelled": rep.cancelled,
        "failed": rep.failed, "shed": rep.shed, "preempted": rep.preempted,
        "migrated": rep.migrated,
        "prefill_energy_j": rep.prefill_energy_j,
        "decode_energy_j": rep.decode_energy_j,
        "idle_energy_j": rep.idle_energy_j,
        "prefill_tokens": rep.prefill_tokens,
        "decode_tokens": rep.decode_tokens,
        "duration_s": rep.duration_s,
        "ttft_pass": rep.ttft_pass, "tbt_pass": rep.tbt_pass,
    }


def run_engine(mesh, cfg, cancel=False, out_len=10):
    """Engine scenario: paged + prefix cache + chunked prefill on a pool
    tight enough to preempt, a mid-run cancel, mixed sampling."""
    from repro.serving import EngineConfig, Server, ServingEngine
    ecfg = EngineConfig(max_batch=8, max_len=96, paged=True,
                        prefix_cache=True, num_pages=16, page_size=16,
                        cache_dtype="float32", governor="defaultnv",
                        mesh=mesh)
    eng = ServingEngine(cfg, ecfg=ecfg, seed=0)
    prompts, reqs = _requests(10, cfg.vocab_size, out_len=out_len)
    for p, r in zip(prompts, reqs):
        eng.submit(r, p)
    eng.step()                        # progress, then cancel a live request
    cancelled = None
    if cancel:
        live = [r.rid for r in eng.pending] + \
            sorted(st.req.rid for st in eng.active.values())
        assert live, "nothing left to cancel after one block"
        cancelled = live[0]
        assert eng.cancel(cancelled)
    Server(eng).run()
    rep = eng.report()
    pc = eng.prefix_cache.stats()
    return {
        "tokens": {r.rid: list(map(int, r.tokens)) for r in reqs},
        "cancelled_rid": cancelled,
        "report": _report_digest(rep),
        "host_drains": eng._host_drains,
        "prefix_hits": pc["hits"], "prefix_hit_tokens": pc["hit_tokens"],
        "buckets": list(eng.buckets), "ctx_buckets": list(eng.ctx_buckets),
        "k_blocks": list(eng._k_blocks),
    }


def run_cluster(mesh):
    """Disaggregated cluster scenario: prefill->decode handoffs on every
    request, plus a replica kill at a deterministic fraction of the healthy
    run's makespan (identical across meshes because tokens are)."""
    from repro.serving import (EngineConfig, FaultPlan, ReplicaKill, Server,
                               ServingCluster)
    cfg = _dense_cfg()
    prompts, reqs = _requests(6, cfg.vocab_size, seed=11, dup_every=6)

    def once(faults=None):
        ecfg = EngineConfig(max_batch=8, max_len=96, cache_dtype="float32",
                            governor="defaultnv", num_pages=32, mesh=mesh)
        cl = ServingCluster(cfg, n_prefill=1, n_decode=2, ecfg=ecfg,
                            seed=0, faults=faults)
        srv = Server(cl)
        handles = [srv.submit(p, r.sampling) for p, r in zip(prompts, reqs)]
        rep = srv.run()
        toks = {i: list(map(int, h.request.tokens))
                for i, h in enumerate(handles)}
        drains = sum(r.engine._host_drains for r in cl.replicas)
        return rep, toks, drains

    healthy_rep, healthy_toks, healthy_drains = once()
    plan = FaultPlan([ReplicaKill(at=0.4 * healthy_rep.duration_s,
                                  replica="decode1")])
    faulted_rep, faulted_toks, _ = once(faults=plan)
    assert faulted_toks == healthy_toks, \
        "replica-kill recovery lost token-exactness"
    return {
        "tokens": healthy_toks,
        "report": _report_digest(healthy_rep),
        "host_drains": healthy_drains,
        "faulted_report": _report_digest(faulted_rep),
    }


def kernel_compiles():
    """Module-level kernel compile counts, accumulated over every scenario
    this worker ran (the satellite compile-budget regression reads these)."""
    from repro.serving import engine as E
    return {name: getattr(E, name)._cache_size()
            for name in ("_prefill_kernel", "_chunk_prefill_kernel",
                         "_decode_block_kernel", "_paged_decode_block_kernel")}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="none",
                    help="'none' (unsharded) or 'dp,tp'")
    ap.add_argument("--scenarios", default="dense,moe,cluster")
    args = ap.parse_args(argv)
    mesh = None if args.mesh == "none" else \
        tuple(int(v) for v in args.mesh.split(","))

    out = {"mesh": args.mesh}
    scenarios = args.scenarios.split(",")
    if "dense" in scenarios:
        out["dense"] = run_engine(mesh, _dense_cfg(), cancel=True,
                                  out_len=24)
    if "moe" in scenarios:
        out["moe"] = run_engine(mesh, _moe_cfg())
    if "cluster" in scenarios:
        out["cluster"] = run_cluster(mesh)
    out["compiles"] = kernel_compiles()
    json.dump(out, sys.stdout)
    sys.stdout.write("\n")


if __name__ == "__main__":
    main()
