"""Paged KV-cache subsystem tests: token-for-token equivalence of paged vs
dense decode and chunked vs one-shot prefill across attention variants,
allocator invariants (no double-free, chains freed at retire, occupancy never
exceeds the pool), pool-pressure preemption with recompute-on-resume, the
over-subscription capacity win, and the Pallas paged decode kernel vs its
oracle.

Equivalence runs use float32 K/V buffers on both sides: the chunked path
reads *past* chunks through the cache while one-shot prefill attends raw
activations, so bf16 buffers would make the comparison a rounding lottery
instead of a correctness check (decode-side reads go through the cache in
both engines, so they are layout-exact at any dtype).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Request
from repro.models import init_params, init_cache, prefill, decode_step
from repro.models.config import ModelConfig
from repro.serving import EngineConfig, Server, ServingEngine
from repro.serving.pager import PageAllocator, SCRATCH_PAGE
import repro.serving.engine as engine_mod

KEY = jax.random.PRNGKey(0)
MAXLEN = 96


def _cfg(variant: str) -> ModelConfig:
    kw = dict(name=f"tp-{variant}", arch_type="dense", num_layers=2,
              d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
              vocab_size=128, dtype="float32", max_seq=512)
    if variant == "gqa":
        kw["num_kv_heads"] = 2
    elif variant == "kv_quant":
        kw.update(num_kv_heads=2, kv_quant=True)
    elif variant == "local":
        kw.update(block_pattern=("local", "full"), window=16)
    return ModelConfig(**kw)


def _reference_tokens(params, cfg, prompt, output_len):
    caches = init_cache(cfg, 1, MAXLEN, dtype=jnp.float32)
    lg, caches, pos = prefill(params, cfg,
                              jnp.asarray(prompt, jnp.int32)[None], caches)
    toks = [int(jnp.argmax(lg[0]))]
    while len(toks) < max(output_len, 2) and pos < MAXLEN - 1:
        lg, caches = decode_step(params, cfg,
                                 jnp.asarray([[toks[-1]]], jnp.int32),
                                 caches, jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    return toks


def _engine(cfg, params, **kw):
    kw.setdefault("cache_dtype", "float32")
    return ServingEngine(cfg, params=params,
                         ecfg=EngineConfig(max_batch=4, max_len=MAXLEN,
                                           governor="defaultnv", **kw))


def _serve(eng, prompts, out_lens):
    reqs = []
    for i, (p, o) in enumerate(zip(prompts, out_lens)):
        r = Request(rid=i, arrival=0.0, prompt_len=len(p), output_len=o)
        reqs.append(r)
        eng.submit(r, p)
    Server(eng).run()
    return [r.tokens for r in reqs]


def _force_chunk(eng, n=16):
    """Shrink the admission buckets so prompts > n take the chunked path even
    on full-attention configs (whose natural bucket cap is max_len // 2)."""
    eng.buckets = [b for b in eng.buckets if b <= n] or [n]
    eng.chunk_len = eng.buckets[-1]


# -- paged vs dense equivalence ------------------------------------------------

@pytest.mark.parametrize("variant", ["full", "gqa", "kv_quant", "local"])
def test_paged_decode_matches_dense(variant):
    """The paged engine emits token-for-token the same output as the dense
    slot-native engine over mixed-position continuous batching."""
    cfg = _cfg(variant)
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (19, 7, 12)]
    outs = [10, 6, 8]

    t_dense = _serve(_engine(cfg, params, paged=False), prompts, outs)
    t_paged = _serve(_engine(cfg, params, paged=True), prompts, outs)
    assert t_dense == t_paged


@pytest.mark.parametrize("variant", ["full", "gqa", "local"])
def test_chunked_prefill_matches_oneshot(variant):
    """A prompt long enough to be split into chunks decodes token-for-token
    like the unchunked reference (one-shot prefill + scalar decode)."""
    cfg = _cfg(variant)
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=37)
    eng = _engine(cfg, params, paged=True)
    _force_chunk(eng)
    [tokens] = _serve(eng, [prompt], [8])
    assert tokens == _reference_tokens(params, cfg, prompt, 8)


@pytest.mark.parametrize("variant", ["rglru", "ssm"])
def test_chunked_prefill_hybrid_recurrent_state_survives_interleaving(variant):
    """A hybrid (recurrent + attention) stream mid-chunked-prefill must not
    have its SSM/RG-LRU row state advanced by other streams' decode blocks:
    recurrent caches have no position masking, so inactive rows' updates are
    frozen via the active mask (regression: decode once polluted the state
    between chunks, K/V buffers alone were protected)."""
    kw = dict(name=f"tp-{variant}", d_model=64, num_heads=4, num_kv_heads=4,
              head_dim=16, d_ff=128, vocab_size=128, dtype="float32",
              max_seq=512)
    if variant == "rglru":
        kw.update(arch_type="hybrid", num_layers=3,
                  block_pattern=("rglru", "rglru", "local"), window=16,
                  lru_width=64, conv_width=4)
    else:
        kw.update(arch_type="hybrid", num_layers=2,
                  block_pattern=("ssm", "local"), window=16,
                  ssm_state=16, ssm_headdim=16, conv_width=4)
    cfg = ModelConfig(**kw)
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(11)
    p_long = rng.integers(0, cfg.vocab_size, size=37)  # > window -> chunked
    p_short = rng.integers(0, cfg.vocab_size, size=9)
    eng = _engine(cfg, params)
    r_short = Request(rid=0, arrival=0.0, prompt_len=9, output_len=12)
    eng.submit(r_short, p_short)
    eng.step(1)                       # short stream decodes alone first
    r_long = Request(rid=1, arrival=0.0, prompt_len=37, output_len=8)
    eng.submit(r_long, p_long)       # chunks interleave with short's decode
    Server(eng).run()
    assert r_long.tokens == _reference_tokens(params, cfg, p_long, 8)
    assert r_short.tokens == _reference_tokens(params, cfg, p_short, 12)


def test_chunked_prefill_kv_quant_layout_equivalence():
    """Under K/V quantization, chunked one-shot equivalence is not exact by
    construction (past chunks are read dequantized, one-shot attends raw), so
    assert the *layout* equivalence instead: paged chunked == dense chunked."""
    cfg = _cfg("kv_quant")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, size=37)]

    def chunked(paged):
        eng = _engine(cfg, params, paged=paged)
        _force_chunk(eng)
        return _serve(eng, prompts, [8])

    assert chunked(True) == chunked(False)


def test_long_prompt_admits_without_legacy_fallback(monkeypatch):
    """A prompt longer than the smallest attention buffer (window=16) goes
    through the slot-native chunked path: the reference ``prefill`` and
    per-request ``init_cache`` must never run."""
    cfg = _cfg("local")
    params = init_params(KEY, cfg)
    eng = _engine(cfg, params, paged=True)   # construction may init_cache
    calls = []
    monkeypatch.setattr(engine_mod, "prefill",
                        lambda *a, **k: calls.append("prefill"))
    monkeypatch.setattr(engine_mod, "init_cache",
                        lambda *a, **k: calls.append("init_cache"))
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, size=33)   # > window=16
    [tokens] = _serve(eng, [prompt], [8])
    assert calls == []
    assert tokens == _reference_tokens(params, cfg, prompt, 8)


# -- capacity: the point of paging ---------------------------------------------

def test_paged_capacity_exceeds_dense_envelope():
    """With a pool of half the dense K/V memory, the paged engine still holds
    ``max_batch`` concurrent streams — strictly more than the
    ``memory / max_len`` streams the dense layout could pin at equal memory —
    with zero preemptions when the live contexts fit."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    ps = 16
    num_pages = (4 * MAXLEN // ps) // 2 + 1       # half dense capacity + scratch
    eng = _engine(cfg, params, paged=True, page_size=ps, num_pages=num_pages)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=16) for _ in range(4)]
    reqs = [Request(rid=i, arrival=0.0, prompt_len=16, output_len=12)
            for i in range(4)]
    for r, p in zip(reqs, prompts):
        eng.submit(r, p)
    eng.step(1)
    s = eng.stats()
    pool_tokens = s["pages_total"] * ps
    dense_streams_at_equal_memory = pool_tokens // MAXLEN
    assert s["active"] == 4 > dense_streams_at_equal_memory
    Server(eng).run()
    s = eng.stats()
    assert s["completed"] == 4 and s["preempted"] == 0
    assert s["pages_used"] == 0          # chains freed at retire


def test_prefill_only_pool_pressure_preempts_instead_of_stalling():
    """Regression: a pool exhausted entirely by *mid-chunked-prefill* streams
    used to stall forever (only decoding streams were preemption victims).
    Two long prompts that cannot both hold their chains must now complete via
    youngest-first preemption + recompute-on-resume, token-exactly."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    # 3 usable pages of 16 tokens; each 40-token prompt needs 3 pages, so the
    # second stream's chunks exhaust the pool while both are still prefilling
    eng = _engine(cfg, params, paged=True, page_size=16, num_pages=4)
    _force_chunk(eng)
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, size=40) for _ in range(2)]
    tokens = _serve(eng, prompts, [6, 6])
    s = eng.stats()
    assert s["completed"] == 2
    assert s["preempted"] > 0
    for p, t in zip(prompts, tokens):
        assert t == _reference_tokens(params, cfg, p, 6)


def test_pool_pressure_preempts_and_recomputes_exactly():
    """An over-committed pool forces preemption; victims are recomputed via
    chunked prefill and still produce token-exact output."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    eng = _engine(cfg, params, paged=True, page_size=16, num_pages=8)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=30) for _ in range(4)]
    tokens = _serve(eng, prompts, [20] * 4)
    s = eng.stats()
    assert s["completed"] == 4
    assert s["preempted"] > 0            # 7 usable pages << 4 * 50 tokens
    assert s["pages_used"] == 0
    for p, t, o in zip(prompts, tokens, [20] * 4):
        assert t == _reference_tokens(params, cfg, p, o)


# -- allocator properties ------------------------------------------------------

def test_allocator_double_free_raises():
    a = PageAllocator(num_pages=8, page_size=16, max_streams=4,
                      max_pages_per_stream=4)
    assert a.ensure(0, 40)               # 3 pages
    a.free_chain(0)
    a.chains[0] = [1]                    # simulate a stale chain
    with pytest.raises(ValueError, match="double free"):
        a.free_chain(0)


def test_allocator_all_or_nothing_and_occupancy_bound():
    a = PageAllocator(num_pages=6, page_size=16, max_streams=4,
                      max_pages_per_stream=8)
    assert a.ensure(0, 48)               # 3 of 5 usable pages
    assert not a.ensure(1, 64)           # needs 4, only 2 left: refused whole
    assert a.pages_used == 3             # refused alloc took nothing
    assert a.ensure(1, 32)
    assert a.pages_used == 5 and a.pages_free == 0
    assert not a.ensure(2, 1)
    assert a.pages_used <= a.num_pages - 1


def test_allocator_random_workload_invariants():
    rng = np.random.default_rng(42)
    a = PageAllocator(num_pages=33, page_size=8, max_streams=8,
                      max_pages_per_stream=12)
    live = {}
    for step in range(400):
        slot = int(rng.integers(0, 8))
        if slot in live and rng.random() < 0.3:
            a.free_chain(slot)
            del live[slot]
            continue
        want = min(live.get(slot, 0) + int(rng.integers(1, 30)),
                   a.max_pages_per_stream * a.page_size)
        if a.ensure(slot, want):
            live[slot] = want
        # invariants: conservation, no aliasing, table consistency
        held = sum(len(c) for c in a.chains.values())
        assert held + a.pages_free == a.num_pages - 1
        assert a.pages_used <= a.num_pages - 1
        all_pages = [p for c in a.chains.values() for p in c]
        assert len(all_pages) == len(set(all_pages))
        assert SCRATCH_PAGE not in all_pages
        for s, chain in a.chains.items():
            assert list(a.table[s, :len(chain)]) == chain
            assert (a.table[s, len(chain):] == SCRATCH_PAGE).all()
    for slot in list(live):
        a.free_chain(slot)
    assert a.pages_used == 0 and a.pages_free == a.num_pages - 1


def test_allocator_rejects_overlong_chain():
    a = PageAllocator(num_pages=32, page_size=8, max_streams=2,
                      max_pages_per_stream=3)
    with pytest.raises(ValueError, match="max_pages_per_stream"):
        a.ensure(0, 8 * 4)


# -- Pallas paged decode kernel ------------------------------------------------

@pytest.mark.parametrize("case", [
    # B, Hq, KH, P, ps, n_pages, hd, window
    (2, 8, 2, 16, 16, 8, 64, 0),
    (3, 4, 4, 12, 8, 6, 128, 0),
    (1, 16, 4, 16, 16, 4, 64, 24),     # GQA + sliding window
])
def test_paged_decode_kernel_matches_oracle(case):
    from repro.kernels import paged_decode_attention, paged_decode_attention_ref
    B, Hq, KH, P, ps, n, hd, win = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (P, ps, KH, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (P, ps, KH, hd), jnp.float32)
    rng = np.random.default_rng(0)
    pt = np.zeros((B, n), np.int32)
    qpos = np.zeros((B,), np.int32)
    for b in range(B):
        cov = int(rng.integers(1, n + 1))        # partial chains: tail pages
        pt[b, :cov] = rng.choice(np.arange(1, P), size=cov, replace=False)
        qpos[b] = rng.integers(0, cov * ps)      # point at scratch, masked
    out = paged_decode_attention(q, kp, vp, jnp.asarray(pt),
                                 jnp.asarray(qpos), window=win,
                                 interpret=True)
    want = paged_decode_attention_ref(q, kp, vp, jnp.asarray(pt),
                                      jnp.asarray(qpos), window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# -- per-phase accounting ------------------------------------------------------

def test_stats_report_per_phase_energy_and_tokens():
    """Engine stats split energy/tokens by phase like sim.replay.Metrics."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    eng = _engine(cfg, params, paged=True)
    rng = np.random.default_rng(1)
    _serve(eng, [rng.integers(0, cfg.vocab_size, size=20)], [10])
    s = eng.stats()
    assert s["prefill_tokens"] == 20
    assert s["decode_tokens"] == 9       # first token is sampled in prefill
    assert s["prefill_energy_j"] > 0 and s["decode_energy_j"] > 0
    assert s["energy_j"] == pytest.approx(
        s["prefill_energy_j"] + s["decode_energy_j"])
