"""Training substrate: loss goes down, microbatching is exact, checkpoints
round-trip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import NOSHARD, init_params, loss_fn
from repro.training import (AdamWConfig, adamw_update, init_opt_state,
                            init_train_state, load_checkpoint, make_train_step,
                            save_checkpoint, schedule)

KEY = jax.random.PRNGKey(0)


def test_loss_decreases_over_steps():
    cfg = get_config("qwen2-1.5b").smoke()
    state = init_train_state(KEY, cfg)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=60), NOSHARD, 1))
    tokens = jax.random.randint(KEY, (4, 64), 0, cfg.vocab_size)
    batch = {"tokens": tokens}   # fixed batch -> should overfit fast
    losses = []
    for i in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_microbatch_grad_equals_full_batch():
    cfg = get_config("granite-8b").smoke().replace(dtype="float32")
    params = init_params(KEY, cfg)
    opt = init_opt_state(params)
    tokens = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    s1 = {"params": params, "opt": opt}
    s2 = jax.tree.map(lambda x: x, s1)
    step1 = make_train_step(cfg, AdamWConfig(), NOSHARD, 1)
    step4 = make_train_step(cfg, AdamWConfig(), NOSHARD, 4)
    o1, m1 = jax.jit(step1)(s1, batch)
    o4, m4 = jax.jit(step4)(s2, batch)
    a = jax.tree.leaves(o1["params"])[0]
    b = jax.tree.leaves(o4["params"])[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=0.05)


def test_grad_clip_limits_update():
    cfg = get_config("qwen2-1.5b").smoke()
    params = init_params(KEY, cfg)
    grads = jax.tree.map(lambda x: jnp.full(x.shape, 1e6, jnp.float32), params)
    opt = init_opt_state(params)
    _, _, metrics = adamw_update(params, grads, opt,
                                 AdamWConfig(grad_clip=1.0))
    assert float(metrics["grad_norm"]) > 1e6  # raw norm reported


def test_checkpoint_roundtrip():
    cfg = get_config("qwen2-1.5b").smoke()
    params = init_params(KEY, cfg)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.msgpack")
        save_checkpoint(path, params)
        loaded = load_checkpoint(path, params)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
