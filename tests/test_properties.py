"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional test dep (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (A100_SXM4_40G, CubicPowerModel, DualLoopController,
                        QuadraticLatencyModel, PrefillOptimizer, TPSFreqTable,
                        deadline_from_queue, make_router)
from repro.models.kvcache import ring_slot_positions
from repro.models.moe import capacity, _slots
from repro.models.config import ModelConfig
from repro.models.transformer import sample_tokens_batched

HW = A100_SXM4_40G


# -- batched per-row sampler ------------------------------------------------------------

def _sampler_case(draw_ints, B=4, V=24):
    """Deterministic logits + per-row lanes from a hypothesis-drawn seed."""
    rng = np.random.default_rng(draw_ints)
    logits = jnp.asarray(rng.normal(0, 3, size=(B, V)), jnp.float32)
    temps = jnp.asarray(rng.choice([0.0, 0.25, 0.7, 1.3], size=B),
                        jnp.float32)
    topk = jnp.asarray(rng.integers(0, V + 2, size=B), jnp.int32)
    topp = jnp.asarray(rng.uniform(0.05, 1.0, size=B), jnp.float32)
    keys = jax.vmap(jax.random.fold_in)(
        jnp.broadcast_to(jax.random.PRNGKey(draw_ints), (B, 2)),
        jnp.arange(B))
    return logits, temps, topk, topp, keys


def _keep_mask(logits, temp, top_k, top_p):
    """NumPy oracle for the admissible-token set of one row."""
    V = logits.shape[-1]
    scaled = np.asarray(logits, np.float64) / (temp if temp > 0 else 1.0)
    order = np.argsort(-scaled, kind="stable")
    keep = np.zeros(V, bool)
    k = V if top_k <= 0 or top_k >= V else top_k
    kth = np.sort(scaled)[::-1][k - 1]
    keep[scaled >= kth] = True           # ties at the cutoff stay admissible
    probs = np.exp(scaled - scaled.max())
    probs = np.where(keep, probs, 0.0)
    probs /= probs.sum()
    cum = 0.0
    nucleus = np.zeros(V, bool)
    for j in order:
        if not keep[j]:
            continue
        # small tolerance: the device filter cumsums in float32, so a token
        # sitting exactly on the nucleus boundary may differ in the last ulp
        if cum < top_p + 1e-4 or top_p >= 1.0:
            nucleus[j] = True
        cum += probs[j]
    return keep & (nucleus if top_p < 1.0 else keep)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_sampler_never_admits_a_masked_logit(seed):
    """Every sampled token lies inside its row's top-k ∩ top-p keep set
    (tie-tolerant oracle: equal logits at the k-th cutoff are admissible)."""
    logits, temps, topk, topp, keys = _sampler_case(seed)
    toks = np.asarray(sample_tokens_batched(logits, temps, topk, topp, keys))
    for r in range(logits.shape[0]):
        if float(temps[r]) == 0.0:
            continue                     # greedy rows checked separately
        keep = _keep_mask(np.asarray(logits[r]), float(temps[r]),
                          int(topk[r]), float(topp[r]))
        assert keep[toks[r]], (r, toks[r], int(topk[r]), float(topp[r]))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_sampler_greedy_rows_bit_identical_to_argmax(seed):
    logits, _, topk, topp, keys = _sampler_case(seed)
    temps = jnp.zeros((logits.shape[0],), jnp.float32)
    toks = sample_tokens_batched(logits, temps, topk, topp, keys)
    assert (np.asarray(toks) ==
            np.asarray(jnp.argmax(logits, axis=-1))).all()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 16), row=st.integers(0, 3))
def test_sampler_rows_are_independent(seed, row):
    """Perturbing row i's logits *and* sampling params never changes any
    other row's token — the per-slot lanes share no state."""
    logits, temps, topk, topp, keys = _sampler_case(seed)
    base = np.asarray(sample_tokens_batched(logits, temps, topk, topp, keys))
    logits2 = logits.at[row].set(-logits[row] + 1.7)
    temps2 = temps.at[row].set(1.9)
    topk2 = topk.at[row].set(3)
    topp2 = topp.at[row].set(0.5)
    pert = np.asarray(sample_tokens_batched(logits2, temps2, topk2, topp2,
                                            keys))
    others = [r for r in range(logits.shape[0]) if r != row]
    assert (base[others] == pert[others]).all()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_sampler_disabled_filters_reduce_to_plain_temperature(seed):
    """top_p=1.0 and top_k=vocab (or 0) leave the logits untouched, so the
    draw is bit-identical to plain per-row temperature sampling."""
    logits, temps, _, _, keys = _sampler_case(seed)
    B, V = logits.shape
    temps = jnp.where(temps > 0, temps, 0.7)      # all rows sample
    ones = jnp.ones((B,), jnp.float32)
    a = sample_tokens_batched(logits, temps, jnp.zeros((B,), jnp.int32),
                              ones, keys)
    b = sample_tokens_batched(logits, temps,
                              jnp.full((B,), V, jnp.int32), ones, keys)
    plain = jax.vmap(
        lambda kk, row, t: jax.random.categorical(kk, row / t))(
        keys, logits, temps).astype(jnp.int32)
    assert (np.asarray(a) == np.asarray(plain)).all()
    assert (np.asarray(b) == np.asarray(plain)).all()


# -- ring buffer invariants ------------------------------------------------------------

@settings(max_examples=100, deadline=None)
@given(buf=st.integers(1, 512), pos=st.integers(0, 5000))
def test_ring_positions_invariants(buf, pos):
    """Slot positions are exactly the last min(buf, n) written positions,
    each stored at slot p % buf."""
    p = np.asarray(ring_slot_positions(buf, pos))
    n = pos  # number of tokens written (positions 0..pos-1)
    expected = set(range(max(0, n - buf), n))
    got = {int(x) for x in p if x >= 0}
    assert got == expected
    for j, v in enumerate(p):
        if v >= 0:
            assert v % buf == j


# -- router ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(lengths=st.lists(st.integers(1, 20000), min_size=1, max_size=50))
def test_router_total_partition(lengths):
    r = make_router(True)
    for L in lengths:
        c = r.classify(L)
        assert c in (0, 1)
        assert (c == 0) == (L <= r.thresholds[0])


# -- optimizer invariants -----------------------------------------------------------------

def _opt():
    L = np.linspace(32, 8192, 30)
    lat = QuadraticLatencyModel.fit(L, 1e-8 * L ** 2 + 1e-4 * L + 0.002, HW.f_max)
    f = HW.ladder()
    pwr = CubicPowerModel.fit(f, 60 + 280 * (f / HW.f_max) ** 3, HW.f_max,
                              HW.p_idle)
    return PrefillOptimizer(lat, pwr, HW, HW.p_idle)


@settings(max_examples=50, deadline=None)
@given(
    lengths=st.lists(st.integers(16, 8192), min_size=0, max_size=20),
    D=st.floats(0.05, 5.0),
)
def test_optimizer_always_on_ladder_and_feasible(lengths, D):
    opt = _opt()
    f, info = opt.choose_frequency(lengths, D)
    ladder = HW.ladder()
    assert np.min(np.abs(ladder - f)) < 1e-6
    if info["feasible"] and lengths:
        assert opt.busy_time(lengths, f) <= D * 1.001


@settings(max_examples=30, deadline=None)
@given(T_ref=st.floats(0.01, 2.0), D=st.floats(0.5, 10.0))
def test_energy_model_nonnegative_and_bounded(T_ref, D):
    opt = _opt()
    E = opt.energy_total(T_ref, D, HW.ladder())
    assert np.all(E > 0)
    assert np.all(np.isfinite(E))


@settings(max_examples=40, deadline=None)
@given(
    lengths=st.lists(st.integers(16, 8192), min_size=1, max_size=12),
    D_loose=st.floats(0.2, 10.0),
    shrink=st.floats(0.05, 0.95),
)
def test_chosen_frequency_monotone_in_deadline_tightness(lengths, D_loose,
                                                         shrink):
    """Tightening the deadline never picks a *lower* clock (the feasible set
    shrinks from the bottom of the ladder; Eq. 14's argmin can only move
    up)."""
    opt = _opt()
    f_loose, _ = opt.choose_frequency(lengths, D_loose)
    f_tight, _ = opt.choose_frequency(lengths, D_loose * shrink)
    assert f_tight >= f_loose


@settings(max_examples=50, deadline=None)
@given(slo=st.floats(0.01, 5.0), wait=st.floats(0.0, 10.0),
       n=st.integers(0, 20))
def test_deadline_from_queue_floor_and_monotonicity(slo, wait, n):
    """D is the remaining TTFT budget of the oldest queued request, floored
    at 1 ms; longer waits never yield looser deadlines."""
    D = deadline_from_queue([64] * n, slo, wait)
    assert D >= 1e-3
    assert D == pytest.approx(max(slo - wait, 1e-3))
    assert deadline_from_queue([64] * n, slo, wait + 0.5) <= D


@settings(max_examples=50, deadline=None)
@given(thresholds=st.lists(st.integers(1, 10000), min_size=1, max_size=4,
                           unique=True))
def test_router_class_boundaries_inclusive_below(thresholds):
    """Each threshold belongs to the class *below* it (classify uses <=):
    classify(t) == i and classify(t + 1) == i + 1 for every cut-off, and
    class indices are monotone in prompt length."""
    from repro.core import LengthRouter
    ts = tuple(sorted(thresholds))
    r = LengthRouter(thresholds=ts,
                     class_names=tuple(f"c{i}" for i in range(len(ts) + 1)))
    for i, t in enumerate(ts):
        assert r.classify(t) == i
        assert r.classify(t + 1) == i + 1 or (t + 1) in ts
    lens = sorted({1, *ts, *(t + 1 for t in ts), 10 ** 6})
    cls = [r.classify(L) for L in lens]
    assert cls == sorted(cls)
    assert r.classify(1) == 0 and r.classify(10 ** 6) == len(ts)


# -- MoE slot assignment ---------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    S=st.integers(4, 64),
    E=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 2),
    seed=st.integers(0, 2 ** 16),
)
def test_moe_slots_unique_per_expert(S, E, k, seed):
    """No two (token, choice) pairs share an (expert, slot) pair."""
    cfg = ModelConfig(name="t", arch_type="moe", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=2, head_dim=16, d_ff=32,
                      vocab_size=32, num_experts=E, experts_per_token=k)
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, E, (1, S, k)), jnp.int32)
    slots = np.asarray(_slots(cfg, idx, C=10 ** 9))
    pairs = set()
    for s in range(S):
        for j in range(k):
            key = (int(idx[0, s, j]), int(slots[0, s, j]))
            assert key not in pairs
            pairs.add(key)
    # slots within each expert are dense 0..count-1
    for e in range(E):
        got = sorted(int(slots[0, s, j]) for s in range(S) for j in range(k)
                     if int(idx[0, s, j]) == e)
        assert got == list(range(len(got)))


# -- controller invariants ----------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_controller_never_leaves_ladder_under_random_load(seed):
    tps = [200, 1000, 3000]
    freqs = HW.ladder()[::4]
    p95 = 0.08 * (np.asarray(tps)[:, None] / 3000.0) * (HW.f_max / freqs[None, :])
    ept = np.tile(np.linspace(0.3, 1.0, len(freqs)), (3, 1))
    table = TPSFreqTable.from_profile(tps, freqs, p95, ept, 0.1, HW.f_step)
    ctl = DualLoopController(HW, table)
    rng = np.random.default_rng(seed)
    t = 0.0
    prev_f = ctl.freq
    for _ in range(500):
        t += float(rng.uniform(0.001, 0.05))
        ctl.record_tokens(t, int(rng.integers(0, 50)),
                          float(rng.uniform(0.005, 0.3)))
        f = ctl.maybe_tick(t)
        assert HW.f_min <= f <= HW.f_max
        lo, _, hi = ctl.band
        assert lo - 1e-9 <= f <= hi + 1e-9
        prev_f = f
