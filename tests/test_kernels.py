"""Per-kernel tests: shape/dtype sweeps + hypothesis, asserting allclose
against the pure-jnp oracles (interpret mode executes the kernel body in
Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional test dep (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels import ref
from repro.models.kvcache import ring_slot_positions

KEY = jax.random.PRNGKey(42)


def _qkv(B, Hq, KH, Sq, Sk, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, KH, Sk, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, KH, Sk, hd)).astype(dtype)
    return q, k, v


FLASH_CASES = [
    # B, Hq, KH, S, hd, window, softcap
    (2, 4, 4, 256, 64, 0, 0.0),          # MHA
    (1, 8, 2, 256, 128, 0, 0.0),         # GQA 4:1
    (1, 16, 1, 128, 128, 0, 0.0),        # MQA
    (2, 4, 2, 384, 64, 128, 0.0),        # sliding window (mixtral-style)
    (1, 2, 2, 256, 256, 0, 50.0),        # softcap + hd 256 (gemma2-style)
    (1, 4, 4, 512, 64, 256, 30.0),       # window + softcap
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    B, Hq, KH, S, hd, win, cap = case
    q, k, v = _qkv(B, Hq, KH, S, S, hd, dtype)
    out = flash_attention(q, k, v, causal=True, window=win, softcap=cap,
                          interpret=True)
    want = ref.reference_attention(q, k, v, causal=True, window=win,
                                   softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("block", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shapes(block):
    bq, bk = block
    q, k, v = _qkv(1, 4, 4, 256, 256, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    want = ref.reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


DECODE_CASES = [
    (2, 8, 2, 512, 64, 0, 300),
    (1, 16, 8, 256, 128, 0, 255),
    (2, 4, 4, 512, 64, 128, 700),    # ring buffer wrapped (pos >= Sk)
    (3, 8, 1, 256, 128, 0, 60),      # partially filled cache
    (1, 16, 16, 256, 256, 0, 100),   # MHA, hd 256
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(case, dtype):
    B, Hq, KH, Sk, hd, win, pos = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, KH, Sk, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, KH, Sk, hd)).astype(dtype)
    kp = jnp.broadcast_to(ring_slot_positions(Sk, pos + 1)[None], (B, Sk))
    qp = jnp.full((B,), pos, jnp.int32)
    out = decode_attention(q, k, v, kp, qp, window=win, interpret=True,
                           block_k=128)
    want = ref.reference_decode_attention(q, k, v, kp, qp, window=win)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@settings(max_examples=15, deadline=None)
@given(
    B=st.integers(1, 3),
    g=st.sampled_from([1, 2, 4]),
    kh=st.sampled_from([1, 2, 4]),
    nblk=st.integers(1, 3),
    hd=st.sampled_from([64, 128]),
    causal=st.booleans(),
)
def test_flash_attention_property(B, g, kh, nblk, hd, causal):
    """Property: kernel == oracle across random GQA geometry."""
    S = 128 * nblk
    q, k, v = _qkv(B, g * kh, kh, S, S, hd, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.reference_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


@settings(max_examples=15, deadline=None)
@given(
    B=st.integers(1, 2),
    g=st.sampled_from([1, 2, 8]),
    kh=st.sampled_from([1, 4]),
    pos=st.integers(0, 1000),
    win=st.sampled_from([0, 128]),
)
def test_decode_attention_property(B, g, kh, pos, win):
    Sk = 512
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, g * kh, 64))
    k = jax.random.normal(ks[1], (B, kh, Sk, 64))
    v = jax.random.normal(ks[2], (B, kh, Sk, 64))
    kp = jnp.broadcast_to(ring_slot_positions(Sk, pos + 1)[None], (B, Sk))
    qp = jnp.full((B,), pos, jnp.int32)
    out = decode_attention(q, k, v, kp, qp, window=win, interpret=True,
                           block_k=128)
    want = ref.reference_decode_attention(q, k, v, kp, qp, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


def test_decode_attention_q8_matches_dequantized_oracle():
    """int8-KV kernel == fp oracle on the dequantized cache (kernel-level
    counterpart of the kv_quant serving feature)."""
    from repro.kernels.decode_attention_q8 import decode_attention_q8
    from repro.models.kvcache import quantize_kv, dequantize_kv
    for (B, Hq, KH, Sk, hd, win, pos) in [(2, 8, 2, 512, 64, 0, 300),
                                          (1, 16, 8, 256, 128, 128, 700)]:
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Hq, hd))
        k = jax.random.normal(ks[1], (B, KH, Sk, hd))
        v = jax.random.normal(ks[2], (B, KH, Sk, hd))
        kq, ksc = quantize_kv(k)
        vq, vsc = quantize_kv(v)
        kp = jnp.broadcast_to(ring_slot_positions(Sk, pos + 1)[None], (B, Sk))
        qp = jnp.full((B,), pos, jnp.int32)
        out = decode_attention_q8(q, kq, ksc, vq, vsc, kp, qp, window=win,
                                  interpret=True, block_k=128)
        kd = dequantize_kv(kq, ksc, jnp.float32)
        vd = dequantize_kv(vq, vsc, jnp.float32)
        want = ref.reference_decode_attention(q, kd, vd, kp, qp, window=win)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5)


def test_rmsnorm_kernel_matches_ref():
    from repro.kernels.rmsnorm import rmsnorm as rms_kernel
    from repro.models.layers import rmsnorm as rms_ref
    for shape in [(4, 37, 256), (2, 128, 512), (3, 64)]:
        x = jax.random.normal(KEY, shape)
        w = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],)) + 1.0
        out = rms_kernel(x, w, interpret=True, block_rows=64)
        want = rms_ref(x, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5)
