"""Production observability tests: the metrics registry and tracer units,
the telemetry sliding-window edge cases they depend on, and the wiring
through the serving planes.

* Registry: counter/gauge/histogram semantics, Prometheus text exposition
  (validated by the repo's own ``parse_prometheus``), the snapshot timeline
  (``record_snapshot`` / ``query`` / ``series``) and its JSONL round-trip.
* Tracer: span/instant/decision recording, ring-buffer drop accounting,
  Chrome-trace structure, JSONL round-trip, and ``decision_at`` (the audit
  primitive: what decision explains the frequency at instant t?).
* Telemetry windows: eviction exactly at the horizon boundary, out-of-order
  timestamps against the high-water clock, and the NaN empty-window
  sentinels (an empty window is "no data", never "zero latency").
* Engine wiring: lifecycle spans, DVFS reason codes, SLO counters — and the
  zero-overhead regression: a run with sinks installed must be *identical*
  (host drains, virtual clock, energy, tokens) to a run without, because
  publication rides existing host-sync points.
* Server retention: ``retain_reports`` bounds handle/backend bookkeeping
  growth under a request storm (the long-lived-server leak fix).
"""
import json
import math

import jax
import numpy as np
import pytest

from repro.core import (MetricsRegistry, OccupancyMeter, Request,
                        SamplingParams, SlidingWindow, TBTMeter, TPSMeter,
                        Tracer, parse_prometheus, read_timeline_jsonl,
                        read_trace_jsonl)
from repro.core.decode_controller import (DecodeControllerConfig,
                                          DualLoopController)
from repro.core.hardware import A100_SXM4_40G
from repro.core.models import TPSFreqTable
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.serving import EngineConfig, Server, ServingCluster, ServingEngine

KEY = jax.random.PRNGKey(0)
MAXLEN = 96

# every reason code a DVFS decision may carry (stable API — see README)
DECODE_REASONS = {"tbt_pressure", "tbt_pressure_sat", "tbt_slack",
                  "tbt_slack_sat", "tbt_hold", "tps_band_init",
                  "tps_band_shift", "occ_pressure", "occ_decay",
                  "band_reclip", "band_adapt_up", "band_adapt_down"}
PREFILL_REASONS = {"empty_queue", "infeasible_fmax", "optimal",
                   "job_slo_floor", "stability_floor"}


def _cfg(**kw) -> ModelConfig:
    base = dict(name="tobs", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                vocab_size=128, dtype="float32", max_seq=512)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    return cfg, init_params(KEY, cfg)


def _engine(cfg, params, **kw):
    ekw = dict(max_batch=4, max_len=MAXLEN, paged=True,
               governor="greenllm")
    ekw.update({k: v for k, v in kw.items()
                if k not in ("metrics", "tracer", "name")})
    return ServingEngine(cfg, params=params, ecfg=EngineConfig(**ekw),
                         **{k: kw[k] for k in ("metrics", "tracer", "name")
                            if k in kw})


def _burst(srv, vocab, n=6, out=10, arrival_gap=0.01):
    rng = np.random.default_rng(0)
    for i in range(n):
        srv.submit(rng.integers(0, vocab, size=int(rng.integers(12, 40))),
                   SamplingParams(max_tokens=out), arrival=arrival_gap * i)
    return srv.run()


# -- metrics registry -----------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help", ("who",))
    c.labels(who="a").inc()
    c.labels(who="a").inc(2.5)
    c.labels(who="b").inc(1)
    g = reg.gauge("g", "", ("who",))
    g.labels(who="a").set(4.0)
    g.labels(who="a").inc(-1.0)
    flat = reg.flat()
    assert flat['c_total{who="a"}'] == 3.5
    assert flat['c_total{who="b"}'] == 1.0
    assert flat['g{who="a"}'] == 3.0
    # counters are monotone
    with pytest.raises(ValueError):
        c.labels(who="a").inc(-1)
    # a family name reused with a different type is a bug, not a new family
    with pytest.raises(ValueError):
        reg.gauge("c_total", "", ("who",))


def test_histogram_cumulative_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "", (), buckets=(0.1, 0.5, 1.0))
    for v in (0.05, 0.1, 0.3, 2.0):
        h.labels().observe(v)
    h.labels().observe(0.4, n=3)     # batch-weighted (shared TBT sample)
    flat = reg.flat()
    assert flat['lat_seconds_bucket{le="0.1"}'] == 2          # 0.05, 0.1
    assert flat['lat_seconds_bucket{le="0.5"}'] == 6          # + 0.3, 0.4x3
    assert flat['lat_seconds_bucket{le="+Inf"}'] == 7
    assert flat["lat_seconds_count"] == 7
    assert abs(flat["lat_seconds_sum"] - (0.05 + 0.1 + 0.3 + 2.0 + 1.2)) \
        < 1e-9


def test_prometheus_render_parses():
    reg = MetricsRegistry()
    reg.counter("a_total", "with \"quotes\" and {braces}",
                ("x",)).labels(x='v"1').inc(2)
    reg.gauge("b", "").labels().set(-1.5)
    reg.histogram("h_s", "", (), buckets=(1.0,)).labels().observe(0.5)
    text = reg.render_prometheus()
    parsed = parse_prometheus(text)
    assert parsed == reg.flat()
    # malformed exposition is rejected, not silently dropped
    with pytest.raises(ValueError):
        parse_prometheus("no_value_here{")


def test_snapshot_timeline_query(tmp_path):
    reg = MetricsRegistry(snapshot_min_dt=0.1)
    g = reg.gauge("v", "").labels()
    g.set(1.0)
    assert reg.record_snapshot(0.0)
    g.set(2.0)
    assert not reg.record_snapshot(0.05)      # throttled by min_dt
    assert reg.record_snapshot(0.2)
    g.set(3.0)
    assert reg.record_snapshot(0.2)           # same t replaces
    assert not reg.record_snapshot(0.1)       # clocks never run backwards
    assert len(reg.timeline) == 2
    assert reg.query(-1.0) is None
    assert reg.query(0.0)["v"] == 1.0
    assert reg.query(0.1)["v"] == 1.0         # last at-or-before
    assert reg.query(5.0)["v"] == 3.0
    assert reg.series("v") == [(0.0, 1.0), (0.2, 3.0)]
    out = tmp_path / "tl.jsonl"
    assert reg.write_timeline_jsonl(str(out)) == 2
    assert read_timeline_jsonl(str(out)) == reg.timeline


# -- tracer ---------------------------------------------------------------------------


def test_tracer_spans_decisions_and_ring(tmp_path):
    tr = Tracer(capacity=4)
    for i in range(6):
        tr.span("prefill", i, 0.1 * i, 0.1 * i + 0.05, replica="p0",
                tokens=32)
    assert len(list(tr.spans())) == 4          # ring kept the newest
    assert tr.dropped_spans == 2
    assert {s.rid for s in tr.spans()} == {2, 3, 4, 5}

    tr = Tracer()
    tr.span("queue", 1, 0.0, 0.2, replica="p0")
    tr.instant("finish", 1, 0.5, replica="d0", tokens=10)
    tr.decision(0.1, "d0", "decode", 990.0, "tbt_slack", p95_tbt=0.03)
    tr.decision(0.3, "d0", "decode", 1005.0, "tbt_pressure", p95_tbt=0.2)
    tr.decision(0.3, "p0", "prefill", 700.0, "optimal", n_jobs=2)
    assert [s.name for s in tr.spans(rid=1)] == ["queue", "finish"]
    assert len(list(tr.decisions(replica="d0"))) == 2
    # the audit primitive: last decision at-or-before t for a replica
    assert tr.decision_at(0.2, "d0").freq_mhz == 990.0
    assert tr.decision_at(0.3, "d0").reason == "tbt_pressure"
    assert tr.decision_at(0.05, "p0", phase="prefill") is None

    # bind() adapts controllers that don't know their replica name
    cb = tr.bind("d1")
    cb(0.7, "decode", 1200.0, "tbt_hold", margin=0.8)
    assert tr.decision_at(0.7, "d1").inputs["margin"] == 0.8

    out = tmp_path / "trace.jsonl"
    n = tr.write_jsonl(str(out))
    assert n == len(list(tr.spans())) + len(list(tr.decisions())) == 6
    back = read_trace_jsonl(str(out))
    assert [s.name for s in back.spans()] == [s.name for s in tr.spans()]
    assert [d.reason for d in back.decisions()] == \
        [d.reason for d in tr.decisions()]


def test_chrome_trace_structure(tmp_path):
    tr = Tracer()
    tr.span("prefill", 3, 0.0, 0.5, replica="prefill0")
    tr.instant("finish", 3, 0.6, replica="decode0")
    tr.decision(0.25, "decode0", "decode", 900.0, "tbt_slack")
    doc = tr.to_chrome_trace()
    evs = doc["traceEvents"]
    # one process per replica, announced by metadata events
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert names == {"prefill0", "decode0"}
    x = next(e for e in evs if e["ph"] == "X")
    assert x["ts"] == 0.0 and x["dur"] == 0.5 * 1e6   # microseconds
    assert x["tid"] == 4                              # rid + 1
    assert any(e["ph"] == "i" and e["name"] == "dvfs:tbt_slack"
               for e in evs)
    out = tmp_path / "c.json"
    tr.write_chrome_trace(str(out))
    assert json.load(open(out))["traceEvents"]


# -- telemetry window edges (satellite: NaN sentinels + eviction) ---------------------


def test_sliding_window_boundary_eviction():
    w = SlidingWindow(horizon=1.0)
    w.push(0.0, 1.0)
    w.push(1.0, 2.0)
    # a sample exactly at (now - horizon) is retained: eviction is strict <
    assert list(w.values(1.0)) == [1.0, 2.0]
    w.push(1.0 + 1e-9, 3.0)
    assert list(w.values(1.0 + 1e-9)) == [2.0, 3.0]


def test_sliding_window_out_of_order():
    w = SlidingWindow(horizon=1.0)
    w.push(5.0, 1.0)          # high-water at 5.0
    w.push(0.5, 99.0)         # stale sample, already outside the window
    w.push(4.5, 2.0)          # out of order but inside the window
    assert sorted(w.values(5.0).tolist()) == [1.0, 2.0]
    # the high-water clock rules: a query at an *earlier* now cannot
    # resurrect evicted samples or evict live ones
    assert sorted(w.values(4.2).tolist()) == [1.0, 2.0]
    assert w.count(5.0) == 2


def test_empty_window_sentinels():
    occ, tbt, tps = OccupancyMeter(1.0), TBTMeter(1.0), TPSMeter(1.0)
    assert math.isnan(occ.mean(0.0)) and math.isnan(occ.peak(0.0))
    assert math.isnan(tbt.p95(0.0)) and math.isnan(tbt.p99(0.0))
    assert tps.tps(0.0) == 0.0          # a rate of zero is a real zero
    # peak after *full* eviction is NaN too — not the stale maximum
    occ.record(0.0, 0.9)
    assert occ.peak(0.0) == 0.9
    assert math.isnan(occ.peak(10.0))
    tbt.record_tbt(0.0, 0.05)
    assert math.isnan(tbt.p95(10.0))


def test_window_property_high_water():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.tuples(st.floats(0, 100), st.floats(0, 1)),
                    min_size=1, max_size=40))
    def prop(samples):
        w = SlidingWindow(horizon=10.0)
        for t, v in samples:
            w.push(t, v)
        hw = max(t for t, _ in samples)
        kept = w.values(hw)
        expect = [v for t, v in samples if t >= hw - 10.0]
        assert sorted(kept) == sorted(expect)

    prop()


# -- DVFS decision log (controller unit) ----------------------------------------------


def _table(hw):
    tps = [200, 1000, 3000]
    freqs = hw.ladder()[::4]
    p95 = 0.08 * (np.asarray(tps)[:, None] / 3000.0) \
        * (hw.f_max / freqs[None, :])
    ept = np.tile(np.linspace(0.3, 1.0, len(freqs)), (3, 1))
    return TPSFreqTable.from_profile(tps, freqs, p95, ept, 0.1, hw.f_step)


def test_dual_loop_controller_reason_codes():
    hw = A100_SXM4_40G
    ctl = DualLoopController(hw, _table(hw), DecodeControllerConfig())
    tr = Tracer()
    ctl.on_decision = tr.bind("d0")
    t = 0.0
    for _ in range(60):                       # ~1.2 s of slow tokens
        ctl.record_tokens(t, 4, 0.2)          # p95 TBT 200ms >> 100ms SLO
        ctl.maybe_tick(t)
        t += 0.02
    ds = list(tr.decisions(replica="d0"))
    assert ds, "a saturating TBT must generate decisions"
    assert {d.reason for d in ds} <= DECODE_REASONS
    assert any(d.reason.startswith("tbt_pressure") for d in ds)
    # every decision's frequency is the controller state at that instant,
    # and its inputs carry the p95 that justified it
    fine = [d for d in ds if d.reason.startswith("tbt_")]
    assert all(d.inputs["p95_tbt"] > 0.1 for d in fine)
    assert tr.decision_at(t, "d0").freq_mhz == ctl.freq


# -- engine wiring --------------------------------------------------------------------


def test_engine_lifecycle_and_metrics(model):
    cfg, params = model
    reg, tr = MetricsRegistry(), Tracer()
    eng = _engine(cfg, params, name="e0", metrics=reg, tracer=tr)
    rep = _burst(Server(eng), cfg.vocab_size)
    assert rep.completed == 6
    flat = reg.flat()
    assert flat['greenllm_requests_total{replica="e0",event="submitted"}'] \
        == 6
    assert flat['greenllm_requests_total{replica="e0",event="completed"}'] \
        == 6
    assert flat['greenllm_tbt_seconds_count{replica="e0"}'] > 0
    assert flat['greenllm_ttft_seconds_count{replica="e0"}'] == 6
    assert flat['greenllm_frequency_mhz{replica="e0"}'] > 0
    # energy counters track the engine's own accounting exactly
    assert abs(flat['greenllm_energy_joules_total'
                    '{replica="e0",phase="decode"}']
               - eng.decode_energy_j) < 1e-6
    spans = {s.name for s in tr.spans()}
    assert {"submit", "queue", "prefill", "decode_block",
            "finish"} <= spans
    assert {d.reason for d in tr.decisions()} <= DECODE_REASONS
    # the timeline is monotone and queryable anywhere inside the run
    times = [t for t, _ in reg.timeline]
    assert times == sorted(times) and len(times) >= 2
    assert reg.query(rep.duration_s / 2) is not None


def test_engine_zero_overhead_regression(model):
    """Observability must ride existing sync points: a run with sinks is
    step-for-step identical to a run without (same host drains, same
    virtual clock, same energy, same tokens)."""
    cfg, params = model

    def run(with_sinks):
        kw = dict(metrics=MetricsRegistry(), tracer=Tracer()) \
            if with_sinks else {}
        eng = _engine(cfg, params, name="z", **kw)
        rep = _burst(Server(eng), cfg.vocab_size)
        return eng, rep

    e0, r0 = run(False)
    e1, r1 = run(True)
    assert e1._host_drains == e0._host_drains
    assert e1.vtime == e0.vtime
    assert e1.energy_j == e0.energy_j
    assert (r1.decode_tokens, r1.prefill_tokens, r1.completed) == \
        (r0.decode_tokens, r0.prefill_tokens, r0.completed)
    # no sink installed -> no metric state anywhere
    assert e0._m is None and e0.metrics is None and e0.tracer is None


def test_engine_evict(model):
    cfg, params = model
    eng = _engine(cfg, params)
    srv = Server(eng)
    h = srv.submit(np.arange(16) % cfg.vocab_size,
                   SamplingParams(max_tokens=4))
    live = srv.submit(np.arange(20) % cfg.vocab_size,
                      SamplingParams(max_tokens=64))
    h.result()
    assert not eng.evict(live.rid)            # live requests stay
    assert eng.evict(h.rid)
    assert all(r.rid != h.rid for r in eng.requests)
    assert h.rid not in eng._tbt
    srv.run()


def test_server_retention_storm(model):
    """retain_reports bounds every per-request structure on a long-lived
    server: handles, backend request rows, TBT records."""
    cfg, params = model
    eng = _engine(cfg, params)
    srv = Server(eng, retain_reports=4)
    rng = np.random.default_rng(1)
    for i in range(24):
        srv.submit(rng.integers(0, cfg.vocab_size, size=16),
                   SamplingParams(max_tokens=3), arrival=0.001 * i)
    rep = srv.run()
    assert rep.completed <= 4                 # only retained rows are scored
    assert len(srv._handles) <= 4 + 4         # retained + max in flight
    assert len(eng.requests) <= 4 + 4
    assert len(eng._tbt) <= 4 + 4
    assert len(srv._terminal_order) <= 4


# -- cluster + simulator wiring -------------------------------------------------------


def test_cluster_observability(model):
    cfg, params = model
    reg, tr = MetricsRegistry(), Tracer()
    cl = ServingCluster(cfg, params=params, n_prefill=1, n_decode=1,
                        ecfg=EngineConfig(max_batch=4, max_len=MAXLEN,
                                          governor="greenllm"),
                        metrics=reg, tracer=tr)
    rep = _burst(Server(cl), cfg.vocab_size)
    assert rep.completed == 6
    flat = reg.flat()
    for r in ("prefill0", "decode0"):
        assert flat[f'greenllm_frequency_mhz{{replica="{r}"}}'] > 0
    # handoffs surface as spans and counters on both ends
    assert flat['greenllm_requests_total'
                '{replica="prefill0",event="exported"}'] == 6
    assert flat['greenllm_requests_total'
                '{replica="decode0",event="imported"}'] == 6
    assert any(s.name == "handoff" for s in tr.spans())
    # per-phase decisions with per-phase reason codes
    pre = {d.reason for d in tr.decisions(replica="prefill0",
                                          phase="prefill")}
    dec = {d.reason for d in tr.decisions(replica="decode0",
                                          phase="decode")}
    assert pre <= PREFILL_REASONS
    assert dec and dec <= DECODE_REASONS
    # kill the decode replica post-run: fault span + counter appear
    cl.kill_replica("decode0")
    assert any(s.name == "replica_kill" and s.replica == "decode0"
               for s in tr.spans())
    assert reg.flat()['greenllm_faults_total'
                      '{replica="decode0",kind="kill"}'] == 1


def test_simulator_observability():
    from repro.data import get_trace
    from repro.sim import ReplayConfig, build_simulator
    from repro.sim.replay import make_plant_fn  # noqa: F401 (sanity import)
    reg, tr = MetricsRegistry(), Tracer()
    rc = ReplayConfig(governor="greenllm")
    sim = build_simulator(_cfg(), A100_SXM4_40G, rc)
    sim.install_observability(reg, tr)
    for r in get_trace("chat_5qps", duration=6.0)[:10]:
        sim.submit(r)
    while sim.step():
        pass
    rep = sim.report()
    assert rep.completed > 0
    flat = reg.flat()
    assert flat['greenllm_requests_total{replica="node",event="submitted"}'] \
        == 10
    assert any(k.startswith("greenllm_frequency_mhz") for k in flat)
    assert sum(v for k, v in flat.items()
               if k.startswith("greenllm_energy_joules_total")) > 0
    assert {s.name for s in tr.spans()} >= {"submit", "queue", "prefill",
                                            "finish"}
    reasons = {d.reason for d in tr.decisions()}
    assert reasons <= (DECODE_REASONS | PREFILL_REASONS)
    assert any(d.phase == "prefill" for d in tr.decisions())
    # simulator evict obeys the same terminal-only contract
    done = next(r.rid for r in sim.requests if r.state.terminal)
    assert sim.evict(done)
    assert all(r.rid != done for r in sim.requests)
