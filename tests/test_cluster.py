"""Disaggregated prefill/decode cluster tests: KV-handoff token equivalence
(a stream prefilled on replica A, exported, and imported into replica B must
decode token-for-token identically to the same request served colocated on
one engine — full/GQA and hybrid SSM/RG-LRU configs, paged pool layout),
role constraints (prefill replicas never decode, decode replicas never admit
raw prompts), allocator adopt/export invariants, shared-clock + idle-energy
accounting, and the occupancy-pressure controller input.

Equivalence runs pin float32 K/V buffers and greedy sampling: migration is
bit-exact at any dtype (pages are copied, not recomputed), but the colocated
reference decodes through the same cache dtype, so f32 removes the rounding
lottery from the comparison (same rationale as tests/test_paging.py).
"""
import jax
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (A100_SXM4_40G, DualLoopController, Request,
                        TPSFreqTable)
from repro.models import init_params, init_cache, prefill, decode_step
from repro.models.config import ModelConfig
from repro.serving import (EngineConfig, Server, ServingCluster,
                           ServingEngine)
from repro.serving.cluster import ClusterDispatcher

KEY = jax.random.PRNGKey(0)
MAXLEN = 96
HW = A100_SXM4_40G


def _cfg(variant: str) -> ModelConfig:
    kw = dict(name=f"tc-{variant}", arch_type="dense", num_layers=2,
              d_model=64, num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
              vocab_size=128, dtype="float32", max_seq=512)
    if variant == "gqa":
        kw["num_kv_heads"] = 2
    elif variant == "hybrid-rglru":
        kw.update(arch_type="hybrid", num_layers=3,
                  block_pattern=("rglru", "rglru", "local"), window=16,
                  lru_width=64, conv_width=4)
    elif variant == "hybrid-ssm":
        kw.update(arch_type="hybrid", num_layers=2,
                  block_pattern=("ssm", "local"), window=16,
                  ssm_state=16, ssm_headdim=16, conv_width=4)
    return ModelConfig(**kw)


def _reference_tokens(params, cfg, prompt, output_len):
    caches = init_cache(cfg, 1, MAXLEN, dtype=jnp.float32)
    lg, caches, pos = prefill(params, cfg,
                              jnp.asarray(prompt, jnp.int32)[None], caches)
    toks = [int(jnp.argmax(lg[0]))]
    while len(toks) < max(output_len, 2) and pos < MAXLEN - 1:
        lg, caches = decode_step(params, cfg,
                                 jnp.asarray([[toks[-1]]], jnp.int32),
                                 caches, jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    return toks


def _ecfg(**kw):
    kw.setdefault("cache_dtype", "float32")
    kw.setdefault("governor", "defaultnv")
    kw.setdefault("max_batch", 4)
    return EngineConfig(max_len=MAXLEN, paged=True, **kw)


def _engine(cfg, params, **kw):
    return ServingEngine(cfg, params=params, ecfg=_ecfg(**kw))


# -- engine-level handoff ------------------------------------------------------

@pytest.mark.parametrize("variant",
                         ["full", "gqa", "hybrid-ssm", "hybrid-rglru"])
def test_handoff_after_prefill_is_token_exact(variant):
    """Prefill on A, export, import into B, decode on B == colocated run."""
    cfg = _cfg(variant)
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(3)
    # > window (16) on hybrids: exercises the chunked path + recurrent state
    prompt = rng.integers(0, cfg.vocab_size, size=37)
    A, B = _engine(cfg, params), _engine(cfg, params)
    req = Request(rid=0, arrival=0.0, prompt_len=37, output_len=10)
    A.submit(req, prompt)
    A._admit()
    while A.prefilling:
        A._advance_chunks()
    [slot] = list(A.active)
    ho = A.export_stream(slot)
    # export is atomic: no residue on A
    assert not A.active and slot in A.free_slots
    if A.pager is not None:
        assert A.pager.pages_used == 0
    assert B.import_stream(ho)
    Server(B).run()
    assert req.tokens == _reference_tokens(params, cfg, prompt, 10)


def test_handoff_mid_decode_is_token_exact():
    """A stream that already decoded on A continues identically on B, while
    a second stream keeps decoding on A (mixed-position batches on both)."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (19, 8)]
    reqs = [Request(rid=i, arrival=0.0, prompt_len=len(p), output_len=12)
            for i, p in enumerate(prompts)]
    A, B = _engine(cfg, params), _engine(cfg, params)
    for r, p in zip(reqs, prompts):
        A.submit(r, p)
    for _ in range(4):
        A.step(1)
    slot = next(s for s, st in A.active.items() if st.req.rid == 0)
    assert B.import_stream(A.export_stream(slot))
    Server(A).run()
    Server(B).run()
    for r, p in zip(reqs, prompts):
        assert r.tokens == _reference_tokens(params, cfg, p, 12)


def test_import_is_all_or_nothing():
    """A refused import (no free pages) takes nothing; it succeeds verbatim
    once capacity frees up."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(7)
    A = _engine(cfg, params)
    B = _engine(cfg, params, page_size=16, num_pages=3)  # 2 usable pages
    prompt = rng.integers(0, cfg.vocab_size, size=40)    # needs 3 pages
    req = Request(rid=0, arrival=0.0, prompt_len=40, output_len=4)
    A.submit(req, prompt)
    A._admit()
    while A.prefilling:
        A._advance_chunks()
    ho = A.export_stream(next(iter(A.active)))
    used_before = B.pager.pages_used
    assert not B.import_stream(ho)
    assert B.pager.pages_used == used_before      # took nothing
    assert not B.active and len(B.free_slots) == B.ecfg.max_batch
    C = _engine(cfg, params)                      # ample pool: same handoff
    assert C.import_stream(ho)
    Server(C).run()
    assert req.tokens == _reference_tokens(params, cfg, prompt, 4)


def test_adopt_chain_matches_export_and_conserves_pages():
    from repro.serving.pager import PageAllocator, SCRATCH_PAGE
    a = PageAllocator(num_pages=9, page_size=8, max_streams=4,
                      max_pages_per_stream=8)
    assert a.ensure(0, 20)                        # 3 pages
    chain = a.export_chain(0)
    assert len(chain) == 3 and a.pages_used == 0
    assert (a.table[0] == SCRATCH_PAGE).all()
    got = a.adopt_chain(1, 3)
    assert got is not None and len(got) == 3
    assert a.pages_used == 3
    with pytest.raises(ValueError, match="already holds"):
        a.adopt_chain(1, 1)
    assert a.adopt_chain(2, 6) is None            # only 5 free: all-or-nothing
    assert a.pages_used == 3


def test_seeded_sampled_handoff_mid_decode_is_draw_exact():
    """A *sampled* stream (fixed seed) handed off mid-decode continues its
    own draw sequence on the adopter: the RNG lane rides the StreamHandoff
    and draw i is fold_in(lane, position i), so the migrated run is
    token-for-token identical to the never-migrated colocated run even
    though the adopting engine was built with a different seed."""
    from repro.core import SamplingParams
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(17)
    prompt = rng.integers(0, cfg.vocab_size, size=21)
    sp = SamplingParams(max_tokens=14, temperature=0.8, top_k=20, seed=42)

    ref = Request(rid=0, arrival=0.0, prompt_len=21, output_len=14,
                  sampling=sp)
    colo = _engine(cfg, params)
    colo.submit(ref, prompt)
    Server(colo).run()

    req = Request(rid=0, arrival=0.0, prompt_len=21, output_len=14,
                  sampling=sp)
    A = _engine(cfg, params)
    B = ServingEngine(cfg, params=params, seed=99, ecfg=_ecfg())
    A.submit(req, prompt)
    for _ in range(4):
        A.step(1)                       # a few draws happen on A
    assert req.tokens_emitted > 1
    ho = A.export_stream(next(iter(A.active)))
    assert ho.rng_lane is not None and ho.sampling is sp
    assert B.import_stream(ho)
    Server(B).run()
    assert req.tokens == ref.tokens


def test_unseeded_sampled_handoff_keeps_the_exporters_lane():
    """Unseeded sampled streams derive their lane from the *exporting*
    engine's key; the adopter must continue that lane (not mint its own),
    so migrated == never-migrated holds without a user-pinned seed."""
    from repro.core import SamplingParams
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(19)
    prompt = rng.integers(0, cfg.vocab_size, size=12)
    sp = SamplingParams(max_tokens=10, temperature=1.0)

    ref = Request(rid=3, arrival=0.0, prompt_len=12, output_len=10,
                  sampling=sp)
    colo = _engine(cfg, params)          # seed 0
    colo.submit(ref, prompt)
    Server(colo).run()

    req = Request(rid=3, arrival=0.0, prompt_len=12, output_len=10,
                  sampling=sp)
    A = _engine(cfg, params)             # same seed 0 -> same derived lane
    B = ServingEngine(cfg, params=params, seed=77, ecfg=_ecfg())
    A.submit(req, prompt)
    for _ in range(3):
        A.step(1)
    assert B.import_stream(A.export_stream(next(iter(A.active))))
    Server(B).run()
    assert req.tokens == ref.tokens


def test_handoff_snapshots_exporter_resolved_defaults():
    """Export snapshots the *resolved* sampling config into the handoff:
    a request submitted with ``temperature=None`` (greedy, the universal
    default — engine-global sampling shims are gone) must arrive on the
    adopter as a concrete ``temperature=0.0``, never as ``None`` left for
    the importer to interpret."""
    from repro.core import SamplingParams
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(29)
    prompt = rng.integers(0, cfg.vocab_size, size=14)
    sp = SamplingParams(max_tokens=10, seed=21)   # temperature=None -> greedy

    ref = Request(rid=0, arrival=0.0, prompt_len=14, output_len=10,
                  sampling=sp)
    colo = _engine(cfg, params)
    colo.submit(ref, prompt)
    Server(colo).run()

    req = Request(rid=0, arrival=0.0, prompt_len=14, output_len=10,
                  sampling=sp)
    A = _engine(cfg, params)
    B = ServingEngine(cfg, params=params, seed=55, ecfg=_ecfg())
    A.submit(req, prompt)
    for _ in range(3):
        A.step(1)
    ho = A.export_stream(next(iter(A.active)))
    assert ho.sampling.temperature == 0.0         # resolved, not None
    assert B.import_stream(ho)
    Server(B).run()
    assert req.tokens == ref.tokens


def test_preempt_recompute_resume_replays_identical_draws():
    """Preemption + recompute-on-resume replays the prompt and the emitted
    tokens through chunked prefill without consuming draws (provisional
    chunk samples touch no lane state), so a seeded sampled stream resumes
    its draw sequence exactly where it left off."""
    from repro.core import SamplingParams
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(23)
    prompt = rng.integers(0, cfg.vocab_size, size=18)
    sp = SamplingParams(max_tokens=16, temperature=0.9, top_p=0.9, seed=13)

    ref = Request(rid=0, arrival=0.0, prompt_len=18, output_len=16,
                  sampling=sp)
    smooth = _engine(cfg, params)
    smooth.submit(ref, prompt)
    Server(smooth).run()

    req = Request(rid=0, arrival=0.0, prompt_len=18, output_len=16,
                  sampling=sp)
    eng = _engine(cfg, params)
    eng.submit(req, prompt)
    for _ in range(4):
        eng.step(1)
    emitted_before = list(req.tokens)
    assert eng._preempt_for_pages()      # youngest (only) stream evicted
    assert req.state.name == "QUEUED" and eng._preempted == 1
    Server(eng).run()
    assert req.tokens[:len(emitted_before)] == emitted_before
    assert req.tokens == ref.tokens


# -- cluster-level -------------------------------------------------------------

def _mini_trace(cfg, n=6, seed=3, mixed_sampling=False):
    from repro.core import SamplingParams
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(6, 40))) for _ in range(n)]
    # every third request samples (seeded): the disaggregated pipeline must
    # carry heterogeneous sampling lanes through dispatch + handoff
    sps = [SamplingParams(temperature=0.8, top_k=16, seed=50 + i)
           if mixed_sampling and i % 3 == 1 else None for i in range(n)]
    reqs = [Request(rid=i, arrival=0.01 * i, prompt_len=len(p),
                    output_len=int(rng.integers(4, 12)), sampling=sp)
            for i, (p, sp) in enumerate(zip(prompts, sps))]
    return reqs, prompts


@pytest.mark.parametrize("governor", ["defaultnv", "greenllm"])
def test_cluster_matches_colocated_engine_tokens(governor):
    """The full disaggregated pipeline (dispatch -> prefill replica ->
    paged-KV handoff -> decode replica) emits exactly the tokens of a single
    colocated engine, under both governors (DVFS changes virtual time and
    energy, never token values — greedy *or* seeded-sampled rows, whose RNG
    lanes ride the handoff)."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    reqs, prompts = _mini_trace(cfg, mixed_sampling=True)
    ref = [Request(rid=r.rid, arrival=0.0, prompt_len=r.prompt_len,
                   output_len=r.output_len, sampling=r.sampling)
           for r in reqs]
    eng = _engine(cfg, params)
    for r, p in zip(ref, prompts):
        eng.submit(r, p)
    Server(eng).run()

    cl = ServingCluster(cfg, n_prefill=1, n_decode=1, params=params,
                        ecfg=_ecfg(governor=governor))
    for r, p in zip(reqs, prompts):
        cl.submit(r, p)
    Server(cl).run()
    st = cl.stats()
    assert st["completed"] == len(reqs)
    for a, b in zip(ref, reqs):
        assert a.tokens == b.tokens


def test_cluster_role_constraints_and_energy_split():
    """Prefill replicas bill no decode tokens, decode replicas no prefill
    tokens (ample pool: no recompute), every stream migrates exactly once,
    and the cluster roll-up conserves energy (active split + idle == total).
    """
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    reqs, prompts = _mini_trace(cfg)
    cl = ServingCluster(cfg, n_prefill=1, n_decode=1, params=params,
                        ecfg=_ecfg(governor="greenllm"))
    for r, p in zip(reqs, prompts):
        cl.submit(r, p)
    Server(cl).run()
    st = cl.stats()
    by_role = {row["role"]: row for row in st["replicas"]}
    assert by_role["prefill"]["decode_tokens"] == 0
    assert by_role["prefill"]["prefill_tokens"] > 0
    assert by_role["decode"]["prefill_tokens"] == 0
    assert by_role["decode"]["decode_tokens"] > 0
    assert by_role["prefill"]["exported"] == len(reqs)
    assert by_role["decode"]["imported"] == len(reqs)
    assert st["handoffs"] == len(reqs)
    total = sum(row["energy_j"] for row in st["replicas"])
    assert st["energy_j"] == pytest.approx(total)
    assert st["energy_j"] == pytest.approx(
        st["prefill_energy_j"] + st["decode_energy_j"]
        + st["idle_energy_j"])
    # shared clock: no replica outruns the makespan, idle billed to it
    assert all(row["vtime_s"] <= st["makespan_s"] + 1e-12
               for row in st["replicas"])


def test_cluster_slo_metrics_report_per_class():
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    reqs, prompts = _mini_trace(cfg)
    cl = ServingCluster(cfg, n_prefill=1, n_decode=1, params=params,
                        ecfg=_ecfg(governor="greenllm"))
    for r, p in zip(reqs, prompts):
        cl.submit(r, p)
    Server(cl).run()
    st = cl.stats()
    assert 0.0 <= st["ttft_pass"] <= 1.0 and 0.0 <= st["tbt_pass"] <= 1.0
    assert "SM" in st["p90_ttft_s"]          # all mini-trace prompts short
    assert all(r.cls == "SM" for r in reqs)
    # the typed report is the source of truth; the legacy stats() dict is
    # derived from it, so the two views must agree field-for-field
    rep = cl.report()
    assert rep.backend == "cluster" and rep.n_requests == len(reqs)
    assert rep.total_energy_j == pytest.approx(st["energy_j"])
    assert rep.p99_tbt_s >= rep.p95_tbt_s >= 0.0
    assert rep.throughput_tok_s > 0
    assert len(rep.requests) == len(reqs)
    assert all(rr.ttft_ok in (True, False) for rr in rep.requests)


def test_dispatcher_prefers_shortest_expected_busy_time():
    """With one candidate loaded and one idle, the queueing-aware pick lands
    on the idle replica; classification still routes long prompts to the L
    class."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    cl = ServingCluster(cfg, n_prefill=2, n_decode=1, params=params,
                        ecfg=_ecfg(governor="greenllm"))
    d = cl.dispatcher
    assert isinstance(d, ClusterDispatcher)
    assert d.classify(1024) == 0 and d.classify(1025) == 1
    p0, p1 = [r for r in cl.replicas if r.role == "prefill"]
    p0.classes = p1.classes = ()             # same class: pure load choice
    for i in range(3):
        p0.engine.pending.append(
            Request(rid=100 + i, arrival=0.0, prompt_len=30, output_len=4))
    req = Request(rid=0, arrival=0.0, prompt_len=24, output_len=4)
    assert d.pick_prefill(req, [p0, p1], cl.optimizer) is p1


def test_colocated_cluster_is_the_single_engine_baseline():
    """A colocated 'cluster' of one replica behaves like one engine (same
    tokens, no handoffs) — the baseline configuration for energy compares."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    reqs, prompts = _mini_trace(cfg, n=4)
    cl = ServingCluster(cfg, n_prefill=0, n_decode=0, n_colocated=1,
                        params=params, ecfg=_ecfg(governor="defaultnv"))
    for r, p in zip(reqs, prompts):
        cl.submit(r, p)
    Server(cl).run()
    st = cl.stats()
    assert st["completed"] == len(reqs) and st["handoffs"] == 0
    ref = [Request(rid=r.rid, arrival=0.0, prompt_len=r.prompt_len,
                   output_len=r.output_len) for r in reqs]
    eng = _engine(cfg, params)
    for r, p in zip(ref, prompts):
        eng.submit(r, p)
    Server(eng).run()
    for a, b in zip(ref, reqs):
        assert a.tokens == b.tokens


def test_no_request_prefills_before_its_arrival():
    """Arrival gating: several arrivals injected in one batch (a long decode
    block on the other replica jumps the cluster clock across them) must not
    be prefilled back-to-back ahead of the lagging prefill replica's clock —
    TTFT is never negative.  Needs a *realistic* plant config: decode blocks
    of a big model span multiple close arrivals (regression: ungated
    ``_admit`` produced first_token < arrival for the tail of the batch)."""
    cfg = _cfg("full")
    big_plant = ModelConfig(
        name="tc-plant", arch_type="dense", num_layers=40, d_model=5120,
        num_heads=40, num_kv_heads=8, head_dim=128, d_ff=13824,
        vocab_size=32000, max_seq=8192)
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=20) for _ in range(5)]
    reqs = [Request(rid=i, arrival=a, prompt_len=20, output_len=40)
            for i, a in enumerate((0.0, 0.3, 0.35, 0.4, 0.45))]
    cl = ServingCluster(cfg, n_prefill=1, n_decode=1, params=params,
                        plant_cfg=big_plant, ecfg=_ecfg(governor="greenllm"))
    for r, p in zip(reqs, prompts):
        cl.submit(r, p)
    Server(cl).run()
    st = cl.stats()
    assert st["completed"] == len(reqs)
    for r in reqs:
        assert r.first_token >= r.arrival - 1e-9, (r.rid, r.ttft)
        assert r.ttft >= 0.0


# -- occupancy-pressure controller input ---------------------------------------

def _flat_table():
    tps = [200, 1000, 3000]
    freqs = HW.ladder()[::4]
    p95 = 0.08 * (np.asarray(tps)[:, None] / 3000.0) \
        * (HW.f_max / freqs[None, :])
    ept = np.tile(np.linspace(0.3, 1.0, len(freqs)), (3, 1))
    return TPSFreqTable.from_profile(tps, freqs, p95, ept, 0.1, HW.f_step)


def test_sustained_occupancy_biases_band_upward_then_releases():
    """High sustained page occupancy shifts the coarse band up (memory
    pressure -> drain faster); low occupancy leaves it at the table value;
    and once an episode ends, the boost decays back to the table band
    instead of ratcheting permanently."""
    def drive(ctl, occ, t0, seconds):
        t = t0
        for _ in range(int(seconds / 0.01)):
            t += 0.01
            ctl.record_tokens(t, 5, 0.08)
            ctl.record_occupancy(t, occ)
            ctl.maybe_tick(t)
        return t
    lo = DualLoopController(HW, _flat_table())
    drive(lo, 0.10, 0.0, 1.0)
    hi = DualLoopController(HW, _flat_table())
    t = drive(hi, 0.97, 0.0, 1.0)
    assert hi.band[1] > lo.band[1]
    assert hi.band[2] <= HW.f_max
    assert hi.band[0] <= hi.freq <= hi.band[2]
    # the boost saturates where lo pins at f_max instead of growing
    # unboundedly (a long episode must not stretch the decay tail)
    assert hi._occ_boost <= int(np.ceil((HW.f_max - HW.f_min) / HW.f_step))
    # pressure episode over: the boost decays back to the table band
    # (occupancy window ~1s to clear, then one f_step down per coarse tick)
    drive(hi, 0.10, t, 3.0)
    assert hi.band[1] == lo.band[1]
    assert hi._occ_boost == 0


def test_engine_feeds_occupancy_to_controller():
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    eng = _engine(cfg, params)
    eng.controller = DualLoopController(HW, _flat_table())
    rng = np.random.default_rng(1)
    req = Request(rid=0, arrival=0.0, prompt_len=16, output_len=8)
    eng.submit(req, rng.integers(0, cfg.vocab_size, size=16))
    Server(eng).run()
    assert len(eng.controller.occ_meter) > 0
