"""Per-request energy attribution + alert engine tests (ROADMAP PR 8).

The tentpole invariant under test: for every backend (engine, cluster,
simulator) the attribution ledger's per-phase mirrors equal the backend's
own energy report **bitwise**, and the exact rational partition satisfies
attributed + idle pool == billed — including across replica kills,
preemption/recompute and KV handoff.  Instrumentation (metrics + tracer +
ledger) must also leave the run step-for-step identical to a bare run,
and burn-rate alerts must fire deterministically on an SLO-violating
trace and reproduce from the timeline (``audit``).
"""
import copy
from fractions import Fraction

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (AlertEngine, AlertRule, CounterfactualPricer,
                        EnergyLedger, MetricsRegistry, SamplingParams,
                        SLOConfig, Tracer, verify_conservation)
from repro.core.hardware import A100_SXM4_40G
from repro.data import get_trace
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.serving import (EngineConfig, FaultPlan, Server, ServingCluster,
                           ServingEngine)
from repro.sim import PlantModel, ReplayConfig, build_simulator

KEY = jax.random.PRNGKey(0)
MAXLEN = 96

CFG = ModelConfig(name="tattr", arch_type="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
                  vocab_size=128, dtype="float32", max_seq=512)


@pytest.fixture(scope="module")
def params():
    return init_params(KEY, CFG)


def _ecfg(**kw):
    kw.setdefault("cache_dtype", "float32")
    kw.setdefault("governor", "greenllm")
    kw.setdefault("max_batch", 4)
    return EngineConfig(max_len=MAXLEN, paged=True, **kw)


def _submit_burst(srv, n=6, out=10, gap=0.02, seed=0, mixed=True):
    rng = np.random.default_rng(seed)
    for i in range(n):
        sp = SamplingParams(max_tokens=out, temperature=0.7, seed=100 + i) \
            if mixed and i % 2 else SamplingParams(max_tokens=out)
        srv.submit(rng.integers(0, CFG.vocab_size,
                                size=int(rng.integers(12, 40))),
                   sp, arrival=gap * i)
    return srv.run()


# -- the ledger itself ---------------------------------------------------------


def test_ledger_exact_partition_and_equal_decode_split():
    led = EnergyLedger()
    led.register("r0")
    led.record_prefill("r0", 1, 0.3, tokens=20, saved_j=0.05)
    led.record_decode("r0", [1, 2, 3], 0.1, saved_j=0.01)
    led.record_idle("r0", 0.07)
    # decode block splits equally among resident streams, exactly
    share = float(Fraction(0.1) / 3)
    assert led.request_energy_j(2) == share
    assert led.request_energy_j(3) == share
    assert led.request_energy_j(1) == float(Fraction(0.3) + Fraction(0.1) / 3)
    # float mirrors accumulate the identical floats in order
    assert led.phase_total("r0", "prefill") == 0.3
    assert led.phase_total("r0", "decode") == 0.1
    assert led.phase_total("r0", "idle") == 0.07
    led.check_exact("r0")           # attributed + pool == billed, rationally
    assert led.idle_pool_j() == 0.07
    row = [dict(replica="r0", prefill_j=0.3, decode_j=0.1, idle_j=0.07)]
    (summ,) = verify_conservation(led, row)
    assert summ["energy_saved_j"] == pytest.approx(0.06)
    # JSONL rows carry the schema the CLI writes
    r = {x["rid"]: x for x in led.rows()}
    assert set(r[1]) >= {"rid", "prefill_j", "decode_j", "energy_j",
                         "energy_saved_j", "tokens", "replicas",
                         "carried_from"}
    # tokens = prompt tokens + one per decode block the stream sat in
    assert r[1]["tokens"] == 21 and r[1]["replicas"] == ["r0"]


def test_conservation_catches_a_missing_joule():
    led = EnergyLedger()
    led.register("r0")
    led.record_prefill("r0", 1, 0.3)
    with pytest.raises(AssertionError):
        verify_conservation(led, [dict(replica="r0", prefill_j=0.4,
                                       decode_j=0.0, idle_j=0.0)])


def test_carry_across_distinct_ledgers_and_shared_ledger_noop():
    a, b = EnergyLedger(), EnergyLedger()
    a.register("src")
    b.register("dst")
    a.record_prefill("src", 7, 0.25, tokens=16, saved_j=0.02)
    carry = a.export_carry("src", 7)
    b.adopt_carry(carry, 7)
    b.record_decode("dst", [7], 0.1)
    # the migrated stream's bill includes its prefill on the old replica
    assert b.request_energy_j(7) == float(Fraction(0.25) + Fraction(0.1))
    assert b.request_saved_j(7) == pytest.approx(0.02)
    (row,) = [x for x in b.rows() if x["rid"] == 7]
    assert row["carried_from"] == ["src"]
    # a cluster shares ONE ledger: adopting a carry from yourself must not
    # double-count
    before = b.request_energy_j(7)
    b.adopt_carry(b.export_carry("dst", 7), 7)
    assert b.request_energy_j(7) == before
    b.adopt_carry(None, 7)          # failed export -> no carry, no-op
    assert b.request_energy_j(7) == before


def test_idle_topup_slot_is_idempotent():
    led = EnergyLedger()
    led.register("r0")
    led.record_idle("r0", 1.0)
    led.set_idle_topup("r0", 0.5)
    led.set_idle_topup("r0", 0.25)   # repeated report(): overwrite, not add
    assert led.phase_total("r0", "idle") == 1.0 + 0.25
    led.set_idle_topup("r0", 0.0)    # dead replica: slot cleared
    assert led.phase_total("r0", "idle") == 1.0


def test_counterfactual_pricer_is_noiseless_and_leaves_live_rng_alone():
    plant = PlantModel(cfg=get_config("qwen3-14b"), hw=A100_SXM4_40G,
                       n_chips=2, noise_sigma=0.3, seed=11)
    twin = copy.deepcopy(plant)
    pr = CounterfactualPricer(plant)
    a = [pr.prefill_j(256) for _ in range(3)]
    b = [pr.decode_j(8, 500.0) for _ in range(3)]
    assert a[0] == a[1] == a[2] > 0.0       # noiseless clone: deterministic
    assert b[0] == b[1] == b[2] > 0.0
    # pricing must never advance the live plant's RNG: the metered run's
    # next noise draw is unchanged vs an untouched twin
    f = plant.hw.f_max / 2
    assert plant.prefill_latency(512, f) == twin.prefill_latency(512, f)
    assert plant.decode_step_latency(4, 300, f) \
        == twin.decode_step_latency(4, 300, f)


# -- engine / cluster / simulator conservation ---------------------------------


def test_engine_conservation_bitwise(params):
    led = EnergyLedger()
    eng = ServingEngine(CFG, params=params, ecfg=_ecfg(), name="e0",
                        ledger=led)
    rep = _submit_burst(Server(eng))
    rows = [dict(replica="e0", prefill_j=rep.prefill_energy_j,
                 decode_j=rep.decode_energy_j, idle_j=rep.idle_energy_j)]
    (summ,) = verify_conservation(led, rows)
    assert summ["attributed_j"] > 0.0
    # per-request fields land in the report, and they sum to the
    # attributed total (idle stays in the explicit unattributed pool)
    per_req = sum(r.energy_j for r in rep.requests)
    assert per_req == pytest.approx(led.attributed_j(), rel=1e-12)
    assert per_req + summ["idle_pool_j"] \
        == pytest.approx(rep.total_energy_j, rel=1e-12)
    assert all(r.energy_j > 0.0 for r in rep.requests)
    assert rep.energy_saved_j == led.saved_total_j()
    # greenllm runs below f_max: the counterfactual must find real savings
    assert rep.energy_saved_j > 0.0


def test_cluster_conservation_under_kill_and_handoff(params):
    plan = FaultPlan.from_seed(3, horizon=1.5,
                               replicas=["prefill0", "decode0", "decode1"])
    cl = ServingCluster(CFG, n_prefill=1, n_decode=2, params=params,
                        ecfg=_ecfg(), faults=plan)
    led = EnergyLedger()
    srv = Server(cl, ledger=led)
    rep = _submit_burst(srv, n=6)
    assert rep.migrated > 0                       # handoffs actually happened
    summ = verify_conservation(led, rep.replicas)  # bitwise, incl. any kill
    assert len(summ) == 3
    # migrated streams carry their prefill bill across replicas
    multi = [r for r in led.rows() if len(r["replicas"]) > 1]
    assert multi and all(r["prefill_j"] > 0 and r["decode_j"] > 0
                         for r in multi)
    # report() is idempotent: the makespan idle top-up must not double-bill
    rep2 = cl.report()
    verify_conservation(led, rep2.replicas)
    assert rep2.idle_energy_j == rep.idle_energy_j


def test_sim_conservation_bitwise():
    cfg = get_config("qwen3-14b")
    sim = build_simulator(cfg, A100_SXM4_40G,
                          ReplayConfig(governor="greenllm"))
    led = EnergyLedger()
    sim.install_observability(ledger=led)
    trace = get_trace("chat_1qps", duration=30)
    sim.run([copy.copy(r) for r in trace])
    rows = [dict(replica=w.wid, prefill_j=w.energy.active_j, decode_j=0.0,
                 idle_j=w.energy.idle_j) for w in sim.prefill]
    rows += [dict(replica=w.wid, prefill_j=0.0, decode_j=w.energy.active_j,
                  idle_j=w.energy.idle_j) for w in sim.decode]
    summ = verify_conservation(led, rows)
    assert sum(s["attributed_j"] for s in summ) > 0.0
    assert led.saved_total_j() > 0.0      # greenllm clocks below f_max


def test_step_identity_with_ledger_installed(params):
    """Attribution must ride existing sync points: metrics + tracer +
    ledger installed is step-for-step identical to a bare run."""
    def run(instrumented):
        kw = dict(metrics=MetricsRegistry(), tracer=Tracer(),
                  ledger=EnergyLedger()) if instrumented else {}
        eng = ServingEngine(CFG, params=params, ecfg=_ecfg(), name="z", **kw)
        rep = _submit_burst(Server(eng))
        return eng, rep

    e0, r0 = run(False)
    e1, r1 = run(True)
    assert e1._host_drains == e0._host_drains
    assert e1.vtime == e0.vtime
    assert e1.energy_j == e0.energy_j
    assert (r1.decode_tokens, r1.prefill_tokens, r1.completed) \
        == (r0.decode_tokens, r0.prefill_tokens, r0.completed)
    assert r0.energy_saved_j == 0.0 and r1.energy_saved_j > 0.0


# -- alert engine --------------------------------------------------------------


def _burn_rule(kind="ttft", window=10.0):
    return AlertRule.burn_rate(
        f"{kind}-burn", "greenllm_slo_total",
        bad_labels={"kind": kind, "outcome": "miss"},
        good_labels={"kind": kind, "outcome": "pass"},
        window_s=window, slo_target=0.9, burn_threshold=1.0, min_events=4,
        severity="page")


def test_burn_rate_math_on_synthetic_timeline():
    reg = MetricsRegistry()
    c = reg.counter("greenllm_slo_total", "", ("replica", "kind", "outcome"))
    eng = AlertEngine(reg, [_burn_rule(window=1.0)])
    reg.record_snapshot(0.0)
    for _ in range(4):
        c.labels(replica="r", kind="ttft", outcome="miss").inc()
    reg.record_snapshot(1.0)
    (a,) = eng.evaluate(1.0)
    # 100% misses against a 90% target = 10x budget burn
    assert a.fired and a.value == pytest.approx(10.0)
    assert eng.firing() == ["ttft-burn"]
    for _ in range(36):
        c.labels(replica="r", kind="ttft", outcome="pass").inc()
    reg.record_snapshot(2.0)
    (r,) = eng.evaluate(2.0)                  # window slid past the misses
    assert not r.fired and eng.firing() == []
    assert eng.audit() == 1
    assert reg.flat()['greenllm_alerts_total'
                      '{rule="ttft-burn",severity="page"}'] == 1


def test_alert_engine_rejects_duplicate_rule_names():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        AlertEngine(reg, [_burn_rule(), _burn_rule()])


def test_burn_rate_alert_fires_on_slo_violating_trace(params):
    """An adversarial SLO config (sub-millisecond TTFT target) makes every
    request a miss: the burn-rate rule must fire during the run, land in
    the alerts counter + tracer, and reproduce from the timeline."""
    reg, tr = MetricsRegistry(), Tracer()
    alerts = AlertEngine(reg, [_burn_rule("ttft"), _burn_rule("tbt")],
                         tracer=tr)
    eng = ServingEngine(
        CFG, params=params, name="a0",
        ecfg=_ecfg(slo=SLOConfig(ttft_sm=1e-4, ttft_long=1e-4,
                                 tbt_p95=1e-6)))
    srv = Server(eng, metrics=reg, tracer=tr, alerts=alerts)
    _submit_burst(srv)
    assert "ttft-burn" in alerts.firing()
    fired = [a for a in alerts.log if a.fired]
    assert fired and alerts.audit() == len(fired)
    flat = reg.flat()
    assert flat['greenllm_alerts_total'
                '{rule="ttft-burn",severity="page"}'] >= 1
    assert any(s.name == "alert" for s in tr.spans())


# -- property: conservation + step identity over random faulty traces ----------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    _HYP = True
except ImportError:                       # driver image may lack hypothesis:
    _HYP = False                          # fall back to fixed seeds below


def _faulty_trace_property(params, seed):
    def run(instrumented):
        plan = FaultPlan.from_seed(seed % 97, horizon=1.0,
                                   replicas=["prefill0", "decode0",
                                             "decode1"])
        cl = ServingCluster(CFG, n_prefill=1, n_decode=2, params=params,
                            ecfg=_ecfg(), faults=plan)
        led = EnergyLedger() if instrumented else None
        kw = dict(metrics=MetricsRegistry(), tracer=Tracer(),
                  ledger=led) if instrumented else {}
        rep = _submit_burst(Server(cl, **kw), n=5, seed=seed)
        return cl, rep, led

    _, r0, _ = run(False)
    cl, r1, led = run(True)
    # step identity: instrumentation changes nothing the run computed
    assert r1.total_energy_j == r0.total_energy_j
    assert r1.duration_s == r0.duration_s
    assert (r1.decode_tokens, r1.prefill_tokens, r1.completed) \
        == (r0.decode_tokens, r0.prefill_tokens, r0.completed)
    # conservation: bitwise mirrors + exact partition on every replica,
    # whatever the fault schedule did (kills, failed handoffs, spikes)
    verify_conservation(led, r1.replicas)


if _HYP:
    @settings(max_examples=3, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(seed=st.integers(min_value=0, max_value=2**16))
    def test_random_faulty_traces_conserve_and_match_bare(params, seed):
        _faulty_trace_property(params, seed)
else:
    @pytest.mark.parametrize("seed", [5, 40961])
    def test_random_faulty_traces_conserve_and_match_bare(params, seed):
        _faulty_trace_property(params, seed)
