"""Pure-JAX controller: invariants + equivalence with the Python controller
on identical 20 ms-aggregated telemetry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional test dep (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import A100_SXM4_40G as HW, DualLoopController, TPSFreqTable
from repro.core.controller_jax import (controller_step, init_state,
                                       make_params, simulate)


def _table():
    tps = [200, 1000, 3000]
    freqs = HW.ladder()[::4]
    p95 = 0.08 * (np.asarray(tps)[:, None] / 3000.0) * (HW.f_max / freqs[None, :])
    ept = np.tile(np.linspace(0.3, 1.0, len(freqs)), (3, 1))
    return TPSFreqTable.from_profile(tps, freqs, p95, ept, 0.1, HW.f_step)


def _python_reference(table, tokens, p95s):
    """Drive the Python controller with the same per-tick aggregates."""
    import dataclasses
    ctl = DualLoopController(HW, dataclasses.replace(
        table, freq_for=table.freq_for.copy()))
    ctl.cfg = dataclasses.replace(ctl.cfg, adapt_period=1e9)  # disable adapt
    freqs = []
    t = 0.0
    for tok, tbt in zip(tokens, p95s):
        t += 0.020
        # emulate aggregate telemetry: one sample carrying the window P95
        ctl.tps_meter.push(t, float(tok))
        ctl.tbt_meter._buf.clear()
        if tbt > 0:
            ctl.tbt_meter.push(t, float(tbt))
        ctl.maybe_tick(t)
        freqs.append(ctl.freq)
    return np.asarray(freqs)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_jax_controller_invariants(seed):
    rng = np.random.default_rng(seed)
    T = 200
    tokens = rng.integers(0, 60, T).astype(float)
    p95s = rng.uniform(0.0, 0.2, T)
    p = make_params(HW, _table())
    state, freqs = simulate(p, tokens, p95s)
    freqs = np.asarray(freqs)
    assert np.all(freqs >= HW.f_min) and np.all(freqs <= HW.f_max)
    # rate limit: one step per tick except when a coarse re-band snaps the
    # set point into the new band (every 10th tick at most)
    jumps = np.abs(np.diff(freqs)) > HW.f_step + 1e-6
    assert jumps.sum() <= len(freqs) / 10 + 1


def test_jax_controller_tracks_load_step():
    """Low load -> low clock; sustained high load -> band rises after
    hysteresis; symmetric on the way down."""
    p = make_params(HW, _table())
    T = 400
    tokens = np.concatenate([np.full(150, 4.0),      # ~200 TPS
                             np.full(150, 70.0),     # ~3500 TPS
                             np.full(100, 4.0)])
    p95s = np.concatenate([np.full(150, 0.03),       # slack
                           np.full(150, 0.12),       # violating
                           np.full(100, 0.03)])
    _, freqs = simulate(p, tokens, p95s)
    freqs = np.asarray(freqs)
    assert freqs[140] < freqs[290]          # ramped up under load
    assert freqs[-1] < freqs[290]           # came back down


def test_jax_controller_vmaps_over_fleets():
    """vmap over 32 controllers with different traces — the batch-sweep use
    case the pure formulation exists for."""
    p = make_params(HW, _table())
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, 60, (32, 100)).astype(float)
    p95s = rng.uniform(0.0, 0.2, (32, 100))
    _, freqs = jax.vmap(lambda t, q: simulate(p, t, q))(
        jnp.asarray(tokens), jnp.asarray(p95s))
    assert freqs.shape == (32, 100)
    assert bool(jnp.all(freqs >= HW.f_min)) and bool(jnp.all(freqs <= HW.f_max))
