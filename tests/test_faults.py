"""Fault-tolerance tests: deterministic fault injection (``serving.faults``),
crash recovery with token-exact replay, handoff-retry backoff, page-pressure
spikes, deadline-aware load shedding, submit/cancel storms, and the
``Server`` watchdog (per-request wall budgets + stuck-backend detection).

The recovery guarantee under test is the strong one: killing a replica
mid-decode and recomputing its streams from the prompt on survivors yields
token sequences *bit-identical* to the uninterrupted run — greedy rows
because f32 decode rows are batch-independent, seeded sampled rows because
the per-stream RNG lane is pinned at first admission and every draw is
``fold_in(lane, position)`` (pure in position, so replay never skews it).
Equivalence runs therefore pin ``cache_dtype="float32"`` and
``governor="defaultnv"`` like tests/test_cluster.py.
"""
import jax
import numpy as np
import pytest

from repro.core import Request, RequestState, SamplingParams
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.serving import (EngineConfig, FaultPlan, HandoffFailure,
                           PagePressureSpike, ReplicaKill, Server,
                           ServingCluster, ServingEngine, WatchdogConfig)

KEY = jax.random.PRNGKey(0)
MAXLEN = 96

CFG = ModelConfig(name="tf-full", arch_type="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
                  d_ff=128, vocab_size=128, dtype="float32", max_seq=512)


@pytest.fixture(scope="module")
def params():
    return init_params(KEY, CFG)


def _ecfg(**kw):
    kw.setdefault("cache_dtype", "float32")
    kw.setdefault("governor", "defaultnv")
    kw.setdefault("max_batch", 4)
    return EngineConfig(max_len=MAXLEN, paged=True, **kw)


def _cluster(params, faults=None, n_decode=2, **kw):
    return ServingCluster(CFG, n_prefill=1, n_decode=n_decode, params=params,
                          ecfg=_ecfg(**kw), faults=faults)


def _mixed_requests(n=6, seed=1, max_tokens=10):
    """Half greedy, half seeded-sampled — recovery must replay both."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, CFG.vocab_size,
                            size=int(rng.integers(8, 24))) for _ in range(n)]
    sps = [SamplingParams(max_tokens=max_tokens, temperature=0.7,
                          seed=100 + i) if i % 2 else
           SamplingParams(max_tokens=max_tokens) for i in range(n)]
    return prompts, sps


def _run_cluster(params, faults=None, n=6, n_decode=2):
    cl = _cluster(params, faults=faults, n_decode=n_decode)
    srv = Server(cl)
    prompts, sps = _mixed_requests(n)
    handles = [srv.submit(p, sp) for p, sp in zip(prompts, sps)]
    rep = srv.run()
    return cl, rep, handles


# -- the fault plan itself -----------------------------------------------------

def test_faultplan_from_seed_is_deterministic():
    kw = dict(horizon=2.0, replicas=["prefill0", "decode0", "decode1"],
              n_kills=1, n_handoff_failures=2, n_pressure_spikes=1)
    a = FaultPlan.from_seed(7, **kw)
    b = FaultPlan.from_seed(7, **kw)
    assert a.events == b.events
    assert a.events != FaultPlan.from_seed(8, **kw).events
    # kills never target the first replica: something must survive
    kills = [e for e in a.events if isinstance(e, ReplicaKill)]
    assert kills and all(k.replica != "prefill0" for k in kills)


def test_faultplan_reset_replays_identically():
    plan = FaultPlan([ReplicaKill(at=0.1, replica="d0"),
                      HandoffFailure(at=0.0, until=1.0, count=2)])
    assert [k.replica for k in plan.due_kills(0.5)] == ["d0"]
    assert plan.due_kills(0.5) == []                 # fired once
    assert plan.fail_import("d1", 0, 0.2) is True
    assert plan.fail_import("d1", 1, 0.3) is True
    assert plan.fail_import("d1", 2, 0.4) is False   # budget consumed
    log_first = list(plan.log)
    plan.reset()
    assert plan.log == []
    assert [k.replica for k in plan.due_kills(0.5)] == ["d0"]
    assert plan.fail_import("d1", 0, 0.2) is True
    assert len(plan.log) == 2 and plan.log == log_first[:2]


def test_faultplan_rejects_unknown_events():
    with pytest.raises(TypeError, match="unknown fault event"):
        FaultPlan(["kill decode1 please"])


# -- crash recovery: the acceptance-criteria test ------------------------------

def test_replica_kill_mid_decode_recovers_token_exact(params):
    """Kill one decode replica mid-run: every stream it held is requeued and
    recomputed from the prompt on survivors, and all tokens — greedy and
    seeded-sampled — are bit-identical to the no-fault run.  The dead
    replica's energy is frozen at the kill and the cluster roll-up still
    conserves energy."""
    _, healthy, h0 = _run_cluster(params)
    assert healthy.completed == len(h0)
    toks0 = [h.request.tokens for h in h0]

    kill_at = 0.4 * healthy.duration_s
    plan = FaultPlan([ReplicaKill(at=kill_at, replica="decode1")])
    cl, rep, h1 = _run_cluster(params, faults=plan)

    assert cl.kills and cl.kills[0][0] == "decode1"
    assert rep.completed == len(h1)               # nobody lost
    assert [h.request.tokens for h in h1] == toks0   # bit-identical
    # energy: the dead row is frozen at its kill-time snapshot, and the
    # per-replica rows still sum to the cluster total
    dead = next(r for r in rep.replicas if r.name == "decode1")
    # the kill is applied at the first step whose clock reading passes `at`
    assert dead.alive is False and dead.killed_at >= kill_at
    assert dead.killed_at == pytest.approx(cl.kills[0][1])
    assert dead.energy_j == pytest.approx(cl.kills[0][2])
    assert sum(r.energy_j for r in rep.replicas) == \
        pytest.approx(rep.total_energy_j)


def test_seeded_plan_kill_recovers_token_exact(params):
    """Same guarantee driven through ``FaultPlan.from_seed`` — the seeded
    schedule is replayable, so the faulty run is exactly reproducible."""
    _, healthy, h0 = _run_cluster(params)
    toks0 = [h.request.tokens for h in h0]
    names = ["prefill0", "decode0", "decode1"]
    plan = FaultPlan.from_seed(3, horizon=healthy.duration_s,
                               replicas=names, n_kills=1,
                               n_handoff_failures=1, n_pressure_spikes=0)
    _, rep, h1 = _run_cluster(params, faults=plan)
    assert rep.completed == len(h1)
    assert [h.request.tokens for h in h1] == toks0
    # and replaying the identical plan gives the identical outcome
    plan.reset()
    _, rep2, h2 = _run_cluster(params, faults=plan)
    assert [h.request.tokens for h in h2] == toks0
    assert rep2.completed == rep.completed


def test_kill_last_decode_replica_degrades_to_colocated(params):
    """Killing the *only* decode replica must not strand prefilled streams:
    the surviving prefill replica converts to colocated and finishes the
    work (graceful degradation, not deadlock)."""
    _, healthy, h0 = _run_cluster(params, n_decode=1)
    toks0 = [h.request.tokens for h in h0]
    kill_at = 0.3 * healthy.duration_s
    plan = FaultPlan([ReplicaKill(at=kill_at, replica="decode0")])
    cl, rep, h1 = _run_cluster(params, faults=plan, n_decode=1)
    assert rep.completed == len(h1)
    assert [h.request.tokens for h in h1] == toks0
    assert cl._replica("prefill0").role == "colocated"


# -- transient handoff failure: retry with backoff -----------------------------

def test_handoff_import_failures_retry_and_complete(params):
    """Injected import failures are retried with capped exponential backoff;
    no stream is dropped and tokens stay exact."""
    _, healthy, h0 = _run_cluster(params)
    toks0 = [h.request.tokens for h in h0]
    plan = FaultPlan([HandoffFailure(at=0.0, count=3)])
    cl, rep, h1 = _run_cluster(params, faults=plan)
    assert cl.import_retries >= 3                 # the injections were hit
    assert ("import_fail" in {k for k, _, _ in plan.log})
    assert rep.completed == len(h1) and rep.migrated == len(h1)
    assert [h.request.tokens for h in h1] == toks0


# -- page-pool pressure spike --------------------------------------------------

def test_page_pressure_spike_is_released_and_pool_invariant_holds(params):
    # fault times ride the virtual clock: scale them to the healthy makespan
    _, healthy, _ = _run_cluster(params)
    plan = FaultPlan([PagePressureSpike(at=0.1 * healthy.duration_s,
                                        duration=0.4 * healthy.duration_s,
                                        replica="decode0", pages=6)])
    cl, rep, h1 = _run_cluster(params, faults=plan)
    assert rep.completed == len(h1)
    pg = cl._replica("decode0").engine.pager
    assert pg.pages_reserved == 0                 # spike fully released
    assert pg.pages_used == 0                     # chains freed at retire
    assert pg.pages_used + pg.pages_free == pg.num_pages - 1
    kinds = [k for k, _, _ in plan.log]
    assert "pressure_on" in kinds and "pressure_off" in kinds


# -- deadline-aware load shedding ----------------------------------------------

def test_oversubscribed_storm_sheds_only_past_deadline(params):
    """A 2x-oversubscribed arrival storm (everything lands in one block
    window): requests whose deadline has passed by the time they reach the
    head of the queue are SHED — and only those — while the run never
    stalls and the cluster roll-up still conserves energy."""
    cl = _cluster(params, n_decode=1)
    srv = Server(cl)
    rng = np.random.default_rng(5)
    generous, tight = [], []
    for i in range(16):                 # 2x the 4+4 slot capacity
        p = rng.integers(0, CFG.vocab_size, size=10)
        # generous deadlines first: they fill the slots, so the tight ones
        # are all past-deadline by the time a slot frees up
        if i < 8:
            generous.append(srv.submit(p, SamplingParams(max_tokens=6),
                                       deadline=1e9))
        else:
            # past before the first slot can possibly free up (the tiny test
            # model's virtual clock advances ~microseconds per step)
            tight.append(srv.submit(p, SamplingParams(max_tokens=6),
                                    deadline=1e-7))
    rep = srv.run()                     # completing at all == no stall
    assert all(h.state is RequestState.FINISHED for h in generous)
    assert all(h.state is RequestState.SHED for h in tight)
    assert rep.completed == len(generous) and rep.shed == len(tight)
    shed_rows = [r for r in rep.requests if r.state is RequestState.SHED]
    assert len(shed_rows) == len(tight)
    assert all(r.deadline_ok is False for r in shed_rows)
    assert sum(r.energy_j for r in rep.replicas) == \
        pytest.approx(rep.total_energy_j)


def test_simulator_sheds_past_deadline_like_the_engine():
    """Deadline-aware admission has simulator parity: the discrete-event
    backend sheds past-deadline queue heads with the same terminal state."""
    from repro.core import A100_SXM4_40G
    from repro.sim import ReplayConfig, build_simulator
    from repro.configs import get_config
    sim = build_simulator(get_config("qwen2-1.5b"), A100_SXM4_40G,
                          ReplayConfig(governor="defaultnv"))
    srv = Server(sim)
    keep = [srv.submit(512, SamplingParams(max_tokens=16), arrival=0.0,
                       deadline=1e9) for _ in range(4)]
    late = [srv.submit(512, SamplingParams(max_tokens=16), arrival=5.0,
                       deadline=1.0) for _ in range(4)]
    rep = srv.run()
    assert all(h.state is RequestState.FINISHED for h in keep)
    assert all(h.state is RequestState.SHED for h in late)
    assert rep.shed == len(late)
    assert all(r.deadline_ok is False for r in rep.requests
               if r.state is RequestState.SHED)


# -- storms: no stalls, no leaks -----------------------------------------------

def _pool_at_baseline(eng):
    assert eng.pager.pages_used == 0
    assert sorted(eng.free_slots) == list(range(eng.ecfg.max_batch))
    assert not eng.active and not eng.prefilling


def test_arrival_storm_in_one_block_window_drains_clean(params):
    """Hundreds of submits landing at the same arrival instant: the engine
    admits in waves, never stalls, and retires every stream with the pool
    back at baseline."""
    eng = ServingEngine(CFG, params=params, ecfg=_ecfg())
    srv = Server(eng)
    rng = np.random.default_rng(7)
    handles = [srv.submit(rng.integers(0, CFG.vocab_size, size=8),
                          SamplingParams(max_tokens=4))
               for _ in range(200)]
    rep = srv.run()
    assert rep.completed == len(handles)
    assert all(h.state is RequestState.FINISHED for h in handles)
    _pool_at_baseline(eng)


def test_cancel_storm_leaks_nothing_and_survivors_are_exact(params):
    """Hundreds of submits with a large interleaved cancel wave: no leaked
    slots or page chains, and every surviving greedy stream emits exactly
    the tokens of the storm-free run (f32 greedy rows are
    batch-composition-independent)."""
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, CFG.vocab_size, size=10) for _ in range(120)]

    def run(cancel):
        eng = ServingEngine(CFG, params=params, ecfg=_ecfg())
        srv = Server(eng)
        hs = [srv.submit(p, SamplingParams(max_tokens=6)) for p in prompts]
        if cancel:
            for h in hs[::3]:
                h.cancel()              # a third die in the queue
            srv._pump()
            for h in hs[1::3]:
                h.cancel()              # a third die queued or in flight
        srv.run()
        return eng, hs

    eng, hs = run(cancel=True)
    _pool_at_baseline(eng)
    st = eng.stats()
    assert st["completed"] + st["cancelled"] == len(prompts)
    assert st["cancelled"] >= len(prompts) // 3
    survivors = [h.request.tokens for h in hs[2::3]
                 if h.state is RequestState.FINISHED]
    _, clean = run(cancel=False)
    clean_toks = [h.request.tokens for h in clean[2::3]]
    assert survivors == clean_toks[:len(survivors)]
    assert len(survivors) == len(clean_toks)      # third wave untouched


# -- the Server watchdog -------------------------------------------------------

def test_watchdog_fails_requests_over_wall_budget(params):
    """A request that exceeds its per-request wall budget (on the backend's
    virtual clock) is failed cleanly mid-run: FAILED terminal state, slot
    and pages released, tokens already produced stay readable, and the
    report scores it."""
    eng = ServingEngine(CFG, params=params,
                        ecfg=_ecfg(max_batch=2, decode_block=4))
    srv = Server(eng, watchdog=WatchdogConfig(request_budget_s=1e-3))
    rng = np.random.default_rng(11)
    h = srv.submit(rng.integers(0, CFG.vocab_size, size=8),
                   SamplingParams(max_tokens=64))
    rep = srv.run()
    assert h.state is RequestState.FAILED
    assert len(h.request.tokens) < 64             # it was cut short...
    assert list(h.tokens()) == h.request.tokens   # ...but stays readable
    assert rep.failed == 1 and rep.completed == 0
    _pool_at_baseline(eng)


def test_watchdog_budget_spares_requests_within_budget(params):
    eng = ServingEngine(CFG, params=params, ecfg=_ecfg())
    srv = Server(eng, watchdog=WatchdogConfig(request_budget_s=1e9))
    h = srv.submit(np.arange(8), SamplingParams(max_tokens=6))
    rep = srv.run()
    assert h.state is RequestState.FINISHED and rep.failed == 0


def test_watchdog_stops_a_stuck_backend():
    """A backend that claims work but makes no progress (clock and token
    counts frozen) is declared stuck after ``stall_rounds`` pump rounds:
    in-flight requests are failed, the driver loop stops instead of
    spinning forever."""

    class Stuck:
        def submit(self, req, prompt_tokens=None):
            self.req = req

        def has_work(self):
            return True

        def step(self):
            pass

        def drain_events(self):
            return []

        def cancel(self, rid):
            return False

        def fail(self, rid):
            self.req.state = RequestState.FAILED
            return True

        @property
        def now(self):
            return 0.0

        def report(self):
            return None

    srv = Server(Stuck(), watchdog=WatchdogConfig(stall_rounds=5))
    h = srv.submit(4, SamplingParams(max_tokens=4))
    rounds = 0
    while srv._pump():
        rounds += 1
        assert rounds < 100, "stall guard never tripped"
    assert srv.stuck is True
    assert rounds == 5
    assert h.state is RequestState.FAILED


# -- prefix-cache interactions (PR 9) ------------------------------------------

def test_cancel_storm_with_prefix_cache_leaks_nothing(params):
    """The cancel storm over shared-prefix traffic with the prefix cache
    enabled: cancelled sharers must not corrupt survivors (bit-identical to
    the storm-free cache-on run) and the only pages left after the drain
    are the cache's own grip — clearing it returns the pool to baseline."""
    rng = np.random.default_rng(21)
    head = rng.integers(0, CFG.vocab_size, size=16)
    prompts = [np.concatenate(
        [head, rng.integers(0, CFG.vocab_size, size=6)]) for _ in range(60)]

    def run(cancel):
        eng = ServingEngine(CFG, params=params,
                            ecfg=_ecfg(prefix_cache=True))
        srv = Server(eng)
        hs = [srv.submit(p, SamplingParams(max_tokens=6)) for p in prompts]
        if cancel:
            for h in hs[::3]:
                h.cancel()
            srv._pump()
            for h in hs[1::3]:
                h.cancel()
        srv.run()
        return eng, hs

    eng, hs = run(cancel=True)
    st = eng.stats()
    assert st["completed"] + st["cancelled"] == len(prompts)
    assert st["cancelled"] >= len(prompts) // 3
    assert st["prefix_cache_hits"] > 0
    # only the cache still holds pages; dropping it restores baseline
    assert eng.pager.pages_used == eng.pager.pages_retained > 0
    eng.prefix_cache.clear()
    _pool_at_baseline(eng)
    survivors = [h.request.tokens for h in hs[2::3]
                 if h.state is RequestState.FINISHED]
    _, clean = run(cancel=False)
    clean_toks = [h.request.tokens for h in clean[2::3]]
    assert survivors == clean_toks[:len(survivors)]
    assert len(survivors) == len(clean_toks)


def test_evict_lapsed_sheds_mid_decode_and_survivors_exact(params):
    """Deadline-aware eviction of admitted streams (opt-in
    ``EngineConfig.evict_lapsed``): a stream whose deadline lapses
    mid-decode is freed through the cancel release path and reported SHED
    with ``deadline_ok is False``; without the flag the same request
    finishes (late).  Survivors are bit-identical either way."""
    rng = np.random.default_rng(23)
    doomed_prompt = rng.integers(0, CFG.vocab_size, size=10)
    other_prompts = [rng.integers(0, CFG.vocab_size, size=10)
                     for _ in range(3)]

    def run(evict, deadline):
        eng = ServingEngine(CFG, params=params,
                            ecfg=_ecfg(evict_lapsed=evict, decode_block=4))
        srv = Server(eng)
        doomed = srv.submit(doomed_prompt, SamplingParams(max_tokens=64),
                            deadline=deadline)
        others = [srv.submit(p, SamplingParams(max_tokens=8))
                  for p in other_prompts]
        rep = srv.run()
        return doomed, others, rep

    # pilot: how long does the doomed stream take unmolested?
    d0, o0, rep0 = run(evict=False, deadline=1e9)
    assert d0.state is RequestState.FINISHED
    lapse = 0.5 * rep0.duration_s          # admits fine, lapses mid-decode

    d1, o1, rep1 = run(evict=False, deadline=lapse)
    assert d1.state is RequestState.FINISHED     # without the flag: late
    d2, o2, rep2 = run(evict=True, deadline=lapse)
    assert d2.state is RequestState.SHED
    assert d2.request.tokens                     # it *was* decoding
    assert len(d2.request.tokens) < 64
    assert rep2.shed == 1
    (row,) = [r for r in rep2.requests if r.state is RequestState.SHED]
    assert row.deadline_ok is False
    # survivors untouched by the eviction (f32 rows are batch-independent)
    assert [h.request.tokens for h in o2] == \
        [h.request.tokens for h in o0] == [h.request.tokens for h in o1]
    assert all(h.state is RequestState.FINISHED for h in o2)
