"""Slot-native serving engine tests: token-for-token equivalence against the
per-request reference path (prefill + scalar-pos decode_step), admission
allocation behavior, jaxpr shape of the slot prefill, and stats accounting.

The two-stream scenarios admit requests at different times so the batch holds
streams at *different* positions — a regression guard for the old engine's
batch-wide ``max(pos)`` decode bug.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Request
from repro.models import (init_params, init_cache, prefill, prefill_into_slot,
                          decode_step)
from repro.models.config import ModelConfig
from repro.serving import EngineConfig, Server, ServingEngine
import repro.serving.engine as engine_mod

KEY = jax.random.PRNGKey(0)
MAXLEN = 96


def _cfg(variant: str) -> ModelConfig:
    kw = dict(name=f"t-{variant}", arch_type="dense", num_layers=2, d_model=64,
              num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
              vocab_size=128, dtype="float32", max_seq=512)
    if variant == "gqa":
        kw["num_kv_heads"] = 2
    elif variant == "kv_quant":
        kw.update(num_kv_heads=2, kv_quant=True)
    elif variant == "local":
        kw.update(block_pattern=("local", "full"), window=16)
    return ModelConfig(**kw)


def _reference_tokens(params, cfg, prompt, output_len):
    """Greedy tokens from the unbatched, unpadded reference path."""
    caches = init_cache(cfg, 1, MAXLEN)
    lg, caches, pos = prefill(params, cfg,
                              jnp.asarray(prompt, jnp.int32)[None], caches)
    toks = [int(jnp.argmax(lg[0]))]
    while len(toks) < max(output_len, 2) and pos < MAXLEN - 1:
        lg, caches = decode_step(params, cfg,
                                 jnp.asarray([[toks[-1]]], jnp.int32),
                                 caches, jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    return toks


def _engine(cfg, params, **kw):
    return ServingEngine(cfg, params=params,
                         ecfg=EngineConfig(max_batch=4, max_len=MAXLEN,
                                           governor="defaultnv", **kw))


@pytest.mark.parametrize("variant", ["full", "gqa", "kv_quant", "local"])
def test_slot_path_matches_reference_mixed_positions(variant):
    """Two streams admitted at different positions produce token-for-token
    the same output as decoding each request alone."""
    cfg = _cfg(variant)
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(3)
    p0 = rng.integers(0, cfg.vocab_size, size=19)
    p1 = rng.integers(0, cfg.vocab_size, size=7)
    r0 = Request(rid=0, arrival=0.0, prompt_len=len(p0), output_len=14)
    r1 = Request(rid=1, arrival=0.0, prompt_len=len(p1), output_len=9)

    eng = _engine(cfg, params)
    eng.submit(r0, p0)
    for _ in range(5):        # r0 decodes alone; r1 joins at a later position
        eng.step(1)
    eng.submit(r1, p1)
    Server(eng).run()

    assert r0.tokens == _reference_tokens(params, cfg, p0, r0.output_len)
    assert r1.tokens == _reference_tokens(params, cfg, p1, r1.output_len)


def test_windowed_prompt_falls_back_to_reference_admission():
    """With chunked prefill disabled, prompts longer than a sliding-window
    buffer can't take the bucketed slot write; the engine must route them
    through the reference prefill and still decode correctly in the shared
    batch.  (The chunked default path is covered in tests/test_paging.py.)"""
    cfg = _cfg("local")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(5)
    p0 = rng.integers(0, cfg.vocab_size, size=33)   # > window=16 -> fallback
    p1 = rng.integers(0, cfg.vocab_size, size=9)    # bucketed
    r0 = Request(rid=0, arrival=0.0, prompt_len=len(p0), output_len=8)
    r1 = Request(rid=1, arrival=0.0, prompt_len=len(p1), output_len=8)
    eng = _engine(cfg, params, chunked_prefill=False)
    assert eng.buckets[-1] == 16
    eng.submit(r0, p0)
    eng.step(1)
    eng.submit(r1, p1)
    Server(eng).run()
    assert r0.tokens == _reference_tokens(params, cfg, p0, r0.output_len)
    assert r1.tokens == _reference_tokens(params, cfg, p1, r1.output_len)


def test_admission_allocates_no_fresh_cache(monkeypatch):
    """Slot-native admission writes into the existing batch cache: after
    engine construction, init_cache must never be called again (the old
    engine allocated a per-request cache and spliced the full batch cache
    on every admission)."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    eng = _engine(cfg, params)
    calls = []
    monkeypatch.setattr(engine_mod, "init_cache",
                        lambda *a, **k: calls.append(a) or init_cache(*a, **k))
    rng = np.random.default_rng(0)
    for i in range(6):
        eng.submit(Request(rid=i, arrival=0.0, prompt_len=12, output_len=6),
                   rng.integers(0, cfg.vocab_size, size=12))
    Server(eng).run()
    assert calls == []


def test_slot_prefill_jaxpr_updates_in_place():
    """The jitted slot prefill lowers cache writes to dynamic_update_slice on
    the batch cache (donation-friendly in-place update), not full-cache
    rebuilds."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    caches = init_cache(cfg, 4, MAXLEN)
    toks = jnp.zeros((1, 16), jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda p, t, l, c, s: prefill_into_slot(p, cfg, t, l, c, s))(
        params, toks, jnp.asarray(11), caches, jnp.asarray(2))
    assert "dynamic_update_slice" in str(jaxpr)


def test_engine_config_not_shared_between_instances():
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    e1 = _engine(cfg, params)
    e2 = _engine(cfg, params)
    assert e1.ecfg is not e2.ecfg
    e1.ecfg.max_len = 17
    assert e2.ecfg.max_len == MAXLEN


def test_stats_counts_finished_not_started():
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    eng = _engine(cfg, params)
    for i in range(3):
        eng.submit(Request(rid=i, arrival=0.0, prompt_len=8, output_len=20))
    eng.step(1)                       # everyone admitted, nobody finished
    s = eng.stats()
    assert s["completed"] == 0
    assert s["active"] == 3
    assert s["pending"] == 0
    Server(eng).run()
    s = eng.stats()
    assert s["completed"] == 3
    assert s["active"] == 0


def test_bucket_list_covers_truncation_cap(monkeypatch):
    """Prompts are truncated to max_len//2, so the bucket list must reach
    that cap (not stop at the last power of two below it) — otherwise
    lengths in (largest_pow2, cap] silently fall back to the legacy path."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    eng = ServingEngine(cfg, params=params,
                        ecfg=EngineConfig(max_batch=2, max_len=192,
                                          governor="defaultnv"))
    assert eng.buckets[-1] == 96
    calls = []
    monkeypatch.setattr(engine_mod, "init_cache",
                        lambda *a, **k: calls.append(a) or init_cache(*a, **k))
    eng.submit(Request(rid=0, arrival=0.0, prompt_len=90, output_len=4))
    Server(eng).run()
    assert calls == []          # 90 > 64 but <= 96: still slot admission


def test_stats_slo_parity_with_sim_metrics():
    """Engine stats report per-class p90 TTFT and TTFT/TBT SLO pass rates
    with the same semantics as sim.replay.compute_metrics, so real-engine
    and simulator replays compare column-for-column."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    eng = _engine(cfg, params)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, arrival=0.0, prompt_len=int(rng.integers(8, 40)),
                    output_len=8) for i in range(5)]
    for r in reqs:
        eng.submit(r, rng.integers(0, cfg.vocab_size, size=r.prompt_len))
    Server(eng).run()
    s = eng.stats()
    for key in ("ttft_pass", "tbt_pass", "p90_ttft_s", "p99_tbt_ms"):
        assert key in s
    assert 0.0 <= s["ttft_pass"] <= 1.0 and 0.0 <= s["tbt_pass"] <= 1.0
    # recompute from ground truth: arrival=0 -> ttft == first_token vtime
    slo = eng.ecfg.slo
    want_pass = sum(1 for r in reqs
                    if r.ttft <= slo.ttft_target(r.cls)) / len(reqs)
    assert s["ttft_pass"] == pytest.approx(want_pass)
    assert s["p90_ttft_s"]["SM"] == pytest.approx(
        float(np.percentile([r.ttft for r in reqs], 90)))
    assert s["p99_tbt_ms"] >= s["p95_tbt_ms"] >= 0.0


def test_mixed_sampling_batch_equivalence():
    """A heterogeneous batch — greedy, plain temperature, and two different
    top-k/top-p rows — runs through ``Server.submit`` with no per-request
    rejection; every greedy row is token-for-token identical to the same
    request served alone, and the seeded sampled rows are reproducible
    across runs (the per-slot RNG lane is a pure function of seed and token
    position, so batch composition cannot perturb the draws)."""
    from repro.core import SamplingParams
    from repro.serving import Server
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=n)
               for n in (19, 7, 12, 26)]
    sps = [SamplingParams(max_tokens=12),                      # greedy
           SamplingParams(max_tokens=12, temperature=0.9, seed=5),
           SamplingParams(max_tokens=12, temperature=0.7, top_k=8, seed=6),
           SamplingParams(max_tokens=12, temperature=1.1, top_p=0.85,
                          seed=7)]

    def run_mixed():
        srv = Server(_engine(cfg, params, cache_dtype="float32"))
        hs = [srv.submit(p, sp) for p, sp in zip(prompts, sps)]
        rep = srv.run()
        assert rep.completed == len(hs)
        return [h.request.tokens for h in hs]

    first = run_mixed()
    assert first == run_mixed()          # seeded rows reproducible
    for i in (0, 1, 2, 3):               # every row == its solo run
        solo = Server(_engine(cfg, params, cache_dtype="float32"))
        h = solo.submit(prompts[i], sps[i])
        solo.run()
        assert h.request.tokens == first[i], f"row {i} perturbed by batch"
    assert first[0] == _reference_tokens(params, cfg, prompts[0], 12)
    # the sampled rows actually sample: distinct draws across the lanes
    assert len({tuple(t) for t in first}) == len(first)


def test_mixed_sampling_joins_mid_decode():
    """A sampled stream admitted while a greedy stream is mid-decode (and
    vice versa) leaves the earlier stream's tokens untouched — the sampled
    lane is per-slot, not a block-global mode switch."""
    from repro.core import SamplingParams
    from repro.serving import Server
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(13)
    p0 = rng.integers(0, cfg.vocab_size, size=17)
    p1 = rng.integers(0, cfg.vocab_size, size=9)

    eng = _engine(cfg, params, cache_dtype="float32")
    srv = Server(eng)
    h0 = srv.submit(p0, SamplingParams(max_tokens=14))          # greedy
    for _ in range(5):
        eng.step(1)                     # h0 decodes alone for a while
    h1 = srv.submit(p1, SamplingParams(max_tokens=10,
                                       temperature=0.8, seed=9))
    srv.run()

    solo = Server(_engine(cfg, params, cache_dtype="float32"))
    s0 = solo.submit(p0, SamplingParams(max_tokens=14))
    solo.run()
    assert h0.request.tokens == s0.request.tokens
    solo = Server(_engine(cfg, params, cache_dtype="float32"))
    s1 = solo.submit(p1, SamplingParams(max_tokens=10,
                                        temperature=0.8, seed=9))
    solo.run()
    assert h1.request.tokens == s1.request.tokens


def test_wall_clock_mode_drains():
    """use_wall_clock=True accounts measured block latency (first-compile
    chunks billed to the plant model) and still drains."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    eng = _engine(cfg, params, use_wall_clock=True)
    for i in range(3):
        eng.submit(Request(rid=i, arrival=0.0, prompt_len=10, output_len=12))
    Server(eng).run()
    s = eng.stats()
    assert s["completed"] == 3
    assert s["vtime_s"] > 0 and s["p95_tbt_ms"] > 0


def test_legacy_engine_still_drains():
    """The pre-slot data plane is kept as a benchmark baseline and must still
    complete lockstep (equal-position) workloads."""
    cfg = _cfg("full")
    params = init_params(KEY, cfg)
    eng = _engine(cfg, params, slot_native=False)
    for i in range(4):
        eng.submit(Request(rid=i, arrival=0.0, prompt_len=10, output_len=8))
    Server(eng).run()
    s = eng.stats()
    assert s["completed"] == 4
