"""Model-substrate unit tests: MoE equivalence, chunked CE, SSM/RG-LRU
recurrence, ring-buffer caches, windowed long-context decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (init_params, forward_train, loss_fn, init_cache,
                          prefill, decode_step)
from repro.models.config import ModelConfig
from repro.models.moe import init_moe, moe_einsum, moe_scatter
from repro.models.ssm import init_ssm, ssm_forward, ssm_decode_step
from repro.models.rglru import init_rglru, rglru_forward, rglru_decode_step
from repro.models.kvcache import init_ssm_cache, init_rglru_cache

KEY = jax.random.PRNGKey(0)


def _moe_cfg(cf=1.25):
    return ModelConfig(name="t", arch_type="moe", num_layers=2, d_model=64,
                       num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128,
                       vocab_size=64, num_experts=4, experts_per_token=2,
                       capacity_factor=cf, dtype="float32")


@pytest.mark.parametrize("cf", [0.5, 1.25, 8.0])
def test_moe_einsum_equals_scatter(cf):
    cfg = _moe_cfg(cf)
    p = init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    ye, ae = moe_einsum(cfg, p, x)
    ys, as_ = moe_scatter(cfg, p, x)
    np.testing.assert_allclose(np.asarray(ye), np.asarray(ys), atol=1e-5)
    assert float(ae) == pytest.approx(float(as_), rel=1e-5)


def test_moe_capacity_drops_tokens():
    """With tiny capacity some tokens are dropped (zero MoE output)."""
    cfg = _moe_cfg(0.1)
    p = init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 64))
    y, _ = moe_einsum(cfg, p, x)
    norms = jnp.linalg.norm(y[0], axis=-1)
    assert float(jnp.min(norms)) == pytest.approx(0.0, abs=1e-6)


@pytest.mark.parametrize("impl", ["einsum", "scatter"])
@pytest.mark.parametrize("cf", [1.0, 1.25])
def test_moe_bucketed_prefill_pads_masked_at_tight_capacity(impl, cf):
    """Bucketed slot prefill at *tight* capacity must match the unpadded
    reference exactly: pads are routed out of expert-capacity competition and
    the per-row capacity is clamped to what the true length would produce
    (the static capacity comes from the padded bucket and is inflated)."""
    from repro.models import prefill_into_slot
    cfg = _moe_cfg(cf).replace(moe_impl=impl, max_seq=256)
    params = init_params(KEY, cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (11,), 0,
                                cfg.vocab_size)
    ref_caches = init_cache(cfg, 1, 64, dtype=jnp.float32)
    lg_ref, _, _ = prefill(params, cfg, prompt[None], ref_caches)
    slot_caches = init_cache(cfg, 2, 64, dtype=jnp.float32)
    padded = jnp.zeros((1, 32), jnp.int32).at[0, :11].set(prompt)
    lg_slot, _, _ = prefill_into_slot(params, cfg, padded, jnp.asarray(11),
                                      slot_caches, jnp.asarray(0))
    np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg_slot),
                               atol=1e-6)


def test_chunked_ce_matches_full():
    cfg = get_config("granite-8b").smoke().replace(dtype="float32")
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 33), 0, cfg.vocab_size)
    l1, _ = loss_fn(params, cfg, {"tokens": tokens}, ce_chunk=8)
    l2, _ = loss_fn(params, cfg, {"tokens": tokens}, ce_chunk=10 ** 9)
    assert float(l1) == pytest.approx(float(l2), abs=1e-4)


def test_ssm_chunked_equals_sequential():
    cfg = ModelConfig(name="t", arch_type="ssm", num_layers=1, d_model=64,
                      num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0,
                      vocab_size=64, block_pattern=("ssm",), ssm_state=16,
                      ssm_expand=2, ssm_headdim=32, ssm_chunk=8,
                      dtype="float32")
    p = init_ssm(KEY, cfg, jnp.float32)
    B, S = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 64))
    cache = init_ssm_cache(cfg, B, dtype=jnp.float32)
    outs = []
    for t in range(S):
        y, cache = ssm_decode_step(cfg, p, x[:, t:t + 1], cache)
        outs.append(y[:, 0])
    seq = jnp.stack(outs, 1)
    full, st = ssm_forward(cfg, p, x, return_state=True)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st["state"]),
                               np.asarray(cache["state"]), atol=2e-5)


def test_rglru_scan_equals_sequential():
    cfg = ModelConfig(name="t", arch_type="hybrid", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=1, head_dim=16, d_ff=64,
                      vocab_size=64, block_pattern=("rglru",), lru_width=32,
                      dtype="float32")
    p = init_rglru(KEY, cfg, jnp.float32)
    B, S = 2, 17
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32))
    cache = init_rglru_cache(cfg, B, dtype=jnp.float32)
    outs = []
    for t in range(S):
        y, cache = rglru_decode_step(cfg, p, x[:, t:t + 1], cache)
        outs.append(y[:, 0])
    seq = jnp.stack(outs, 1)
    full, st = rglru_forward(cfg, p, x, return_state=True)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(cache["h"]),
                               atol=2e-5)


def test_windowed_long_context_decode():
    """Ring-buffer decode (long_context) == full-cache decode restricted to
    the same window."""
    cfg = get_config("granite-8b").smoke().replace(
        dtype="float32", long_context_window=16)
    params = init_params(KEY, cfg)
    B, S = 1, 32
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    # windowed-cache serving path
    caches = init_cache(cfg, B, 64, long_context=True, dtype=jnp.float32)
    assert caches[0][0]["k"].shape[2] == 16   # ring buffer
    _, caches, pos = prefill(params, cfg, tokens[:, :S - 1], caches)
    lg_ring, _ = decode_step(params, cfg, tokens[:, S - 1:], caches, pos)
    # reference: full-attention model with an explicit window-16 mask
    cfg_win = cfg.replace(block_pattern=("local",), window=16)
    caches2 = init_cache(cfg_win, B, 64, dtype=jnp.float32)
    _, caches2, pos2 = prefill(params, cfg_win, tokens[:, :S - 1], caches2)
    lg_full, _ = decode_step(params, cfg_win, tokens[:, S - 1:], caches2, pos2)
    np.testing.assert_allclose(np.asarray(lg_ring), np.asarray(lg_full),
                               atol=2e-4)


def test_param_count_consistency():
    """Analytic param_count matches the actual initialized tree."""
    for arch in ("granite-8b", "mamba2-370m", "mixtral-8x7b",
                 "recurrentgemma-9b", "gemma2-9b"):
        cfg = get_config(arch).smoke()
        params = init_params(KEY, cfg)
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(actual - est) / actual < 0.05, (arch, actual, est)


def test_int8_kv_cache_decode_close_to_fp():
    """kv_quant: teacher-forced decode within 5% of the fp cache path."""
    import jax
    cfg = get_config("granite-8b").smoke().replace(dtype="float32")
    cfgq = cfg.replace(kv_quant=True)
    params = init_params(KEY, cfg)
    B, S = 2, 24
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    outs = {}
    for name, c in (("fp", cfg), ("q8", cfgq)):
        caches = init_cache(c, B, 64, dtype=jnp.float32)
        _, caches, pos = prefill(params, c, tokens[:, :S - 1], caches)
        lg, _ = decode_step(params, c, tokens[:, S - 1:], caches, pos)
        outs[name] = lg
    rel = float(jnp.max(jnp.abs(outs["fp"] - outs["q8"]))) \
        / float(jnp.max(jnp.abs(outs["fp"])))
    assert rel < 0.05, rel
    # the quantized cache really is int8
    cq = init_cache(cfgq, B, 64)
    assert cq[0][0]["k"].dtype == jnp.int8
    assert "k_s" in cq[0][0]
