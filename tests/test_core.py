"""GreenLLM control-plane unit tests (paper §3.1-§3.3)."""
import numpy as np
import pytest

from repro.core import (A100_SXM4_40G, CubicPowerModel, DualLoopController,
                        DecodeControllerConfig, LengthRouter, PrefillOptimizer,
                        QuadraticLatencyModel, SLOConfig, TPSFreqTable,
                        TPSMeter, TBTMeter, make_router)
from repro.core.prefill_optimizer import deadline_from_queue

HW = A100_SXM4_40G


# -- §3.1 router --------------------------------------------------------------------

def test_router_partitions_by_threshold():
    r = make_router(True)
    assert r.classify(10) == 0 and r.classify(1024) == 0
    assert r.classify(1025) == 1 and r.classify(100000) == 1
    single = make_router(False)
    assert single.num_classes == 1
    assert single.classify(100000) == 0


# -- §3.2 latency/power fits + optimizer ---------------------------------------------

def _lat_model():
    L = np.linspace(32, 8192, 40)
    t = 1e-8 * L ** 2 + 1e-4 * L + 0.003
    return QuadraticLatencyModel.fit(L, t, f_ref=HW.f_max)


def test_quadratic_fit_recovers_coefficients():
    m = _lat_model()
    assert m.r2(np.linspace(32, 8192, 40),
                1e-8 * np.linspace(32, 8192, 40) ** 2
                + 1e-4 * np.linspace(32, 8192, 40) + 0.003) > 0.999
    assert abs(m.a - 1e-8) / 1e-8 < 1e-3
    # Eq. 3: latency scales with f_ref / f
    np.testing.assert_allclose(m.predict(1000, HW.f_max / 2),
                               2 * m.predict(1000, HW.f_max), rtol=1e-6)


def test_cubic_power_fit():
    f = HW.ladder()
    P = 60 + 1e-7 * f ** 3 + 0.02 * f
    m = CubicPowerModel.fit(f, P, HW.f_max, HW.p_idle)
    np.testing.assert_allclose(m.predict(f), P, rtol=2e-2)


def _optimizer():
    lat = _lat_model()
    f = HW.ladder()
    # active floor well above idle (uncore), cubic dynamic part — the shape
    # measured in the paper's Fig. 8
    P = 130 + 240 * (f / HW.f_max) ** 3 + 40 * (f / HW.f_max)
    pwr = CubicPowerModel.fit(f, P, HW.f_max, HW.p_idle)
    return PrefillOptimizer(lat, pwr, HW, HW.p_idle)


def test_optimizer_respects_deadline():
    opt = _optimizer()
    lengths = [512, 1024, 2048]
    for D in (0.2, 0.5, 1.0, 4.0):
        f, info = opt.choose_frequency(lengths, D)
        if info["feasible"]:
            assert opt.busy_time(lengths, f) <= D * 1.001
        assert HW.f_min <= f <= HW.f_max


def test_optimizer_monotone_in_deadline():
    """Looser deadlines never pick higher clocks (Eq. 12 is U-shaped)."""
    opt = _optimizer()
    lengths = [1024] * 4
    fs = [opt.choose_frequency(lengths, D)[0] for D in (0.15, 0.3, 0.6, 1.2, 2.4)]
    assert all(a >= b for a, b in zip(fs, fs[1:])), fs


def test_optimizer_infeasible_returns_fmax():
    opt = _optimizer()
    f, info = opt.choose_frequency([8192] * 50, 0.01)
    assert f == HW.f_max and not info["feasible"]


def test_energy_curve_is_u_shaped():
    """E_total(f) over the ladder has an interior minimum (Fig. 3)."""
    opt = _optimizer()
    T_ref = 0.2
    D = 2.0
    E = opt.energy_total(T_ref, D, HW.ladder())
    i = int(np.argmin(E))
    assert 0 < i < len(E) - 1, "energy minimum should be interior"
    assert E[0] > E[i] and E[-1] > E[i]


def test_deadline_from_queue():
    assert deadline_from_queue([1], 0.4, 0.1) == pytest.approx(0.3)
    assert deadline_from_queue([1], 0.4, 5.0) == pytest.approx(1e-3)


# -- §3.3 dual-loop controller ----------------------------------------------------------

def _table():
    tps = [200, 1000, 3000]
    freqs = HW.ladder()[::4]
    # P95 TBT worsens with load and improves with clock -> buckets map to
    # distinct frequencies
    p95 = 0.08 * (np.asarray(tps)[:, None] / 3000.0) * (HW.f_max / freqs[None, :])
    ept = np.tile(np.linspace(0.3, 1.0, len(freqs)), (3, 1))
    return TPSFreqTable.from_profile(tps, freqs, p95, ept, 0.1, HW.f_step)


def test_controller_fine_loop_steps_are_rate_limited():
    ctl = DualLoopController(HW, _table())
    t = 0.0
    for i in range(200):
        t += 0.005
        ctl.record_tokens(t, 5, 0.150)   # consistently violating TBT
    prev = None
    freqs = []
    ctl.maybe_tick(t)
    for _, f, _ in [(0, ctl.freq, 0)]:
        freqs.append(f)
    lo, mid, hi = ctl.band
    assert lo <= ctl.freq <= hi


def test_controller_tracks_band_and_ladder():
    ctl = DualLoopController(HW, _table())
    rng = np.random.default_rng(0)
    t = 0.0
    for i in range(2000):
        t += 0.01
        tbt = float(rng.uniform(0.02, 0.14))
        ctl.record_tokens(t, rng.integers(1, 20), tbt)
        f = ctl.maybe_tick(t)
        lo, mid, hi = ctl.band
        assert HW.f_min <= f <= HW.f_max
        assert lo - 1e-9 <= f <= hi + 1e-9


def test_controller_raises_freq_on_violation_and_lowers_on_slack():
    cfg = DecodeControllerConfig()
    ctl = DualLoopController(HW, _table(), cfg)
    # feed slack -> frequency should drift to the band floor
    t = 0.0
    for i in range(300):
        t += 0.02
        ctl.record_tokens(t, 10, 0.030)  # margin 0.3 < 0.65
        ctl.maybe_tick(t)
    assert ctl.freq == pytest.approx(ctl.band[0])
    f_low = ctl.freq
    # now violate -> frequency should climb to the band ceiling
    for i in range(300):
        t += 0.02
        ctl.record_tokens(t, 10, 0.150)
        ctl.maybe_tick(t)
    assert ctl.freq >= f_low
    assert ctl.freq == pytest.approx(ctl.band[2])


def test_coarse_hysteresis_requires_three_intervals():
    ctl = DualLoopController(HW, _table())
    t = 0.0
    ctl.record_tokens(t, 1, 0.05)
    ctl.maybe_tick(0.001)
    band0 = ctl.band
    # one burst interval should not retarget the band; three should
    for i in range(2):
        t += 0.2
        ctl.record_tokens(t, 600, 0.05)   # ~3000 TPS
        ctl.maybe_tick(t + 1e-3)
    assert ctl.band == band0
    for i in range(3):
        t += 0.2
        ctl.record_tokens(t, 600, 0.05)
        ctl.maybe_tick(t + 1e-3)
    assert ctl.band != band0


# -- telemetry ------------------------------------------------------------------------------

def test_tps_meter_window():
    m = TPSMeter(0.2)
    m.record_tokens(0.0, 10)
    m.record_tokens(0.1, 10)
    assert m.tps(0.1) == pytest.approx(100.0)
    assert m.tps(10.0) == 0.0


def test_tbt_p95():
    m = TBTMeter(10.0)
    for i in range(100):
        m.record_tbt(i * 0.01, 0.01 * (1 + i % 10))
    assert 0.08 <= m.p95(1.0) <= 0.11
