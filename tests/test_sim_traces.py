"""Trace synthesis + simulator plumbing tests."""
import copy

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import SLOConfig
from repro.core.hardware import A100_SXM4_40G
from repro.data import alibaba_chat, azure_code, azure_conv, get_trace
from repro.sim import (NodeConfig, PlantModel, ReplayConfig, build_simulator,
                       compute_metrics, profile_decode_table, profile_power,
                       profile_prefill_latency)

HW = A100_SXM4_40G


def test_trace_reproducible_and_rate():
    a = alibaba_chat(5, duration=200, seed=7)
    b = alibaba_chat(5, duration=200, seed=7)
    assert [r.prompt_len for r in a] == [r.prompt_len for r in b]
    rate = len(a) / 200
    assert 3.5 <= rate <= 6.5


def test_trace_families_differ():
    code = azure_code(5, duration=300)
    conv = azure_conv(5, duration=300)
    mp_code = np.median([r.prompt_len for r in code])
    mp_conv = np.median([r.prompt_len for r in conv])
    mo_code = np.median([r.output_len for r in code])
    mo_conv = np.median([r.output_len for r in conv])
    assert mp_code > mp_conv          # code prompts are longer
    assert mo_code < mo_conv          # code outputs are shorter


def test_plant_phase_asymmetry():
    """Prefill is compute-bound (latency ~1/f); decode is memory-bound
    (latency saturates with f) — paper §2.2, derived not asserted."""
    plant = PlantModel(cfg=get_config("qwen3-14b"), hw=HW, n_chips=2,
                       noise_sigma=0.0)
    t_lo = plant.prefill_latency(2048, HW.f_min)
    t_hi = plant.prefill_latency(2048, HW.f_max)
    assert t_lo / t_hi > 3.0          # strong frequency scaling
    d_lo = plant.decode_step_latency(8, 1000, HW.f_max / 2)
    d_hi = plant.decode_step_latency(8, 1000, HW.f_max)
    assert d_lo / d_hi < 1.3          # saturating (memory-bound)


def test_plant_energy_u_curve():
    """Fixed-clock total energy on a real trace is convex (Fig. 3c)."""
    cfg = get_config("qwen3-14b")
    trace = get_trace("chat_8qps", duration=60)
    from repro.sim import replay
    energies = []
    for f in (HW.f_min, 660.0, HW.f_max):
        m = replay(cfg, trace, ReplayConfig(governor="fixed", fixed_freq=f))
        energies.append(m.total_energy_j)
    assert energies[1] < energies[0] and energies[1] < energies[2], energies


def test_profiling_models_fit_well():
    plant = PlantModel(cfg=get_config("qwen3-14b"), hw=HW, n_chips=2,
                       noise_sigma=0.01, seed=3)
    lat = profile_prefill_latency(plant)
    L = np.linspace(64, 8192, 20)
    t = [plant.prefill_latency(int(x), HW.f_max) for x in L]
    assert lat.r2(L, t) > 0.95
    pwr = profile_power(plant)
    # cubic power fit is monotone increasing over the ladder
    P = pwr.predict(HW.ladder())
    assert np.all(np.diff(P) > -1.0)


def test_decode_table_monotone():
    """Higher TPS buckets never get lower clocks."""
    plant = PlantModel(cfg=get_config("qwen3-14b"), hw=HW, n_chips=1,
                       noise_sigma=0.0)
    table = profile_decode_table(plant)
    assert np.all(np.diff(table.freq_for) >= -plant.hw.f_step / 2)


def test_energy_meter_accounts_full_horizon():
    cfg = get_config("qwen3-14b")
    trace = get_trace("chat_1qps", duration=60)
    sim = build_simulator(cfg, HW, ReplayConfig(governor="defaultNV"))
    res = sim.run([copy.copy(r) for r in trace])
    # every worker's energy covers the sim horizon at >= idle power
    for w in sim.prefill + sim.decode:
        min_j = w.plant.idle_power * res.duration * 0.99
        assert w.energy.total_j >= min_j


def test_all_requests_complete():
    cfg = get_config("qwen3-14b")
    trace = get_trace("chat_3qps", duration=60)
    sim = build_simulator(cfg, HW, ReplayConfig(governor="greenllm"))
    res = sim.run([copy.copy(r) for r in trace])
    assert all(r.finish >= 0 for r in res.requests)
    assert all(r.tokens_emitted == r.output_len for r in res.requests)
