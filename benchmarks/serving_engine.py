"""Serving-engine data-plane benchmark: slot-native vs the pre-PR (legacy)
engine — and the paged KV cache vs the dense slot layout — wall-clock
measured on the smoke config.

Metrics per (governor, batch):

* ``decode``  — steady-state decode tokens/s with a full batch of
  never-ending streams (no admissions in the window): isolates the jitted
  block decode (ctx-bucketed, scanned, donated, no per-token host sync)
  against the legacy per-step host-synced loop.
* ``admit``   — admissions/s: jitted bucketed slot prefill vs the legacy
  eager prefill + fresh per-request cache + host-side full-batch splice.
* ``serve``   — sustained serving tokens/s with continuous batching churn
  (finite outputs, streams join/leave): the end-to-end engine number.
* ``serve ... mixed_sampling`` — the same churn with heterogeneous
  per-request sampling (greedy / temperature / top-k / top-p rows sharing
  each batch through the per-slot sampling lanes): overhead vs the
  all-greedy serve number, and the CI smoke that the mixed path drains.

Paged scenarios (``--paged``):

* ``decode/serve _paged`` — the same workloads through the page-table data
  plane (gathered page chains, chain growth at block boundaries).
* ``longadmit`` — chunked admission of prompts longer than the smallest
  attention buffer (sliding-window config) vs the legacy eager-prefill
  fallback.
* ``capacity`` — concurrent streams sustained on a pool of *half* the dense
  K/V memory: the dense layout pins ``memory / max_len`` streams; paging
  holds ``max_batch`` (the acceptance lever for GreenLLM's decode batching).

Prefix-cache scenario (``--prefix-cache``):

* ``engine_prefix_cache`` — a shared-system-prompt burst served cold vs
  with the content-addressed prefix cache: prefill tokens computed, hit
  rate, and (full-size plant accounting) energy per request.  Output
  tokens are hard-asserted identical between the two runs;
  ``compare.py`` gates the saved-token fraction and the energy ratio.

Cluster scenario (``--cluster``):

* ``cluster_disagg_1p1d`` — a 2-replica disaggregated prefill/decode cluster
  (paged-KV handoff, per-phase DVFS) vs a 2x-colocated max-frequency
  baseline on the same mini-trace: tokens/s, energy ratio (incl. idle up to
  the shared makespan), handoff and preemption counts.  ``--governors ""``
  skips the per-governor engine scenarios and runs only this one (CI smoke).

Mesh scenario (``--mesh dp,tp`` or ``--mesh auto``):

* ``engine_mesh_dp{D}tp{T}`` — the same burst served on a sharded device
  mesh vs the unsharded engine: output tokens hard-asserted identical
  (the PR 10 bit-exactness invariant), and the energy-per-token ratio is
  emitted for ``compare.py`` to hold inside its strict parity band.

    PYTHONPATH=src python benchmarks/serving_engine.py [--quick] [--paged]
        [--cluster] [--mesh 2,4] [--arch qwen2-1.5b] [--batches 1,4,8]
        [--governors greenllm,defaultnv] [--json out.json]

Prints ``name,value,derived`` CSV rows like benchmarks/run.py.  ``--json``
additionally writes the rows (plus the run configuration) as a JSON
document — the format of the checked-in ``BENCH_*.json`` baselines that
make the perf trajectory diffable across PRs.
"""
from __future__ import annotations

import argparse
import gc
import json
import statistics
import time

import jax
import numpy as np


def _engine(cfg, params, *, batch, governor, slot_native, max_len=256,
            paged=False, num_pages=0, chunked=True):
    from repro.serving import EngineConfig, ServingEngine
    return ServingEngine(cfg, params=params, ecfg=EngineConfig(
        max_batch=batch, max_len=max_len, governor=governor,
        slot_native=slot_native, paged=paged, num_pages=num_pages,
        chunked_prefill=chunked))


def _fill(eng, batch, *, prompt_len=24, output_len=10 ** 9, rng=None):
    from repro.core import Request
    for i in range(batch):
        pl = prompt_len if rng is None else int(rng.integers(8, 100))
        eng.submit(Request(rid=i, arrival=0.0, prompt_len=pl,
                           output_len=output_len))
    eng._admit()


def bench_decode(cfg, params, *, batch, governor, slot_native, steps,
                 paged=False):
    eng = _engine(cfg, params, batch=batch, governor=governor,
                  slot_native=slot_native, paged=paged)
    _fill(eng, batch)
    # warm the (ctx, k) kernels outside the timed window
    for _ in range(2):
        eng._decode_block(16) if slot_native else eng._step_legacy()
    jax.block_until_ready(eng._tok)
    t0 = time.perf_counter()
    if slot_native:
        eng._decode_block(steps)
    else:
        for _ in range(steps):
            eng._step_legacy()
    jax.block_until_ready(eng.caches)
    return batch * steps / (time.perf_counter() - t0)


def bench_admit(cfg, params, *, governor, slot_native, n):
    eng = _engine(cfg, params, batch=8, governor=governor,
                  slot_native=slot_native)
    from repro.core import Request
    eng.submit(Request(rid=10 ** 6, arrival=0.0, prompt_len=24, output_len=4))
    eng._admit()                       # compile warmup
    eng._retire(list(eng.active.keys()))
    jax.block_until_ready(eng._tok)
    for i in range(n):
        eng.submit(Request(rid=i, arrival=0.0, prompt_len=24, output_len=4))
    t0 = time.perf_counter()
    while eng.pending:
        eng._admit()
        jax.block_until_ready(eng._tok)
        eng._retire(list(eng.active.keys()))
    return n / (time.perf_counter() - t0)


def bench_serve(cfg, params, *, batch, governor, slot_native, nreq, out_len,
                paged=False):
    """Sustained serving through the ``serving.api`` front door (the same
    driver loop production callers use)."""
    from repro.core import SamplingParams
    from repro.serving import Server
    eng = _engine(cfg, params, batch=batch, governor=governor,
                  slot_native=slot_native, paged=paged)
    srv = Server(eng)
    rng = np.random.default_rng(0)
    for _ in range(nreq):
        srv.submit(rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(8, 100))),
                   SamplingParams(max_tokens=out_len))
    t0 = time.perf_counter()
    srv.run()
    jax.block_until_ready(eng._tok)
    return nreq * out_len / (time.perf_counter() - t0)


def bench_long_admit(cfg, params, *, governor, n, chunked):
    """Admission latency for prompts longer than the smallest attention
    buffer: chunked slot-native admission vs the legacy eager-prefill
    fallback.  Requires a sliding-window config (see bench caller)."""
    from repro.core import Request
    eng = _engine(cfg, params, batch=8, governor=governor, slot_native=True,
                  chunked=chunked)
    long_len = min(eng.ecfg.max_len // 2, eng.buckets[-1] * 4)

    def admit_one(rid):
        eng.submit(Request(rid=rid, arrival=0.0, prompt_len=long_len,
                           output_len=4))
        eng._admit()
        while eng.prefilling:
            eng._advance_chunks()
        jax.block_until_ready(eng._tok)
        eng._retire(list(eng.active.keys()))

    admit_one(10 ** 6)                 # compile warmup
    t0 = time.perf_counter()
    for i in range(n):
        admit_one(i)
    return n / (time.perf_counter() - t0)


def bench_paged_capacity(cfg, params, *, governor, nreq, out_len):
    """Streams sustained concurrently on half the dense K/V memory.

    Returns (streams, dense_equivalent_streams, tokens_per_s): the paged
    engine runs ``nreq`` concurrent streams against a pool whose token
    capacity would pin only ``pool_tokens / max_len`` dense rows.
    """
    from repro.core import SamplingParams
    from repro.serving import Server
    max_len = 256
    ps = 16
    num_pages = (nreq * max_len // ps) // 2 + 1     # half dense memory
    eng = _engine(cfg, params, batch=nreq, governor=governor,
                  slot_native=True, max_len=max_len, paged=True,
                  num_pages=num_pages)
    srv = Server(eng)
    rng = np.random.default_rng(0)
    for _ in range(nreq):
        srv.submit(rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(16, 64))),
                   SamplingParams(max_tokens=out_len))
    eng._admit()
    peak = len(eng.active) + len(eng.prefilling)
    t0 = time.perf_counter()
    rep = srv.run()
    jax.block_until_ready(eng._tok)
    dt = time.perf_counter() - t0
    # usable pool size from the allocator (page 0 is reserved scratch)
    dense_eq = (eng.pager.occupancy()["pages_total"] * ps) // max_len
    return peak, dense_eq, rep.decode_tokens / dt


def bench_mixed_sampling(cfg, params, *, batch, governor, nreq, out_len):
    """Sustained serving of a heterogeneous sampling mix (greedy /
    temperature / top-k / top-p rows sharing each batch) through the
    ``serving.api`` front door — the scenario the engine-global-temperature
    design rejected outright.  Returns (tok/s, greedy-fraction-served)."""
    from repro.core import SamplingParams
    from repro.serving import Server
    eng = _engine(cfg, params, batch=batch, governor=governor,
                  slot_native=True)
    srv = Server(eng)
    rng = np.random.default_rng(0)
    mixes = [SamplingParams(max_tokens=out_len),
             SamplingParams(max_tokens=out_len, temperature=0.9, seed=1),
             SamplingParams(max_tokens=out_len, temperature=0.7, top_k=40,
                            seed=2),
             SamplingParams(max_tokens=out_len, temperature=1.1, top_p=0.9,
                            seed=3)]
    hs = []
    for i in range(nreq):
        hs.append(srv.submit(
            rng.integers(0, cfg.vocab_size, size=int(rng.integers(8, 100))),
            mixes[i % len(mixes)]))
    t0 = time.perf_counter()
    rep = srv.run()
    jax.block_until_ready(eng._tok)
    dt = time.perf_counter() - t0
    assert rep.completed == nreq, "mixed-sampling smoke must drain"
    greedy = sum(1 for i in range(nreq) if i % len(mixes) == 0)
    return nreq * out_len / dt, greedy / nreq


def bench_prefix_cache(cfg, params, *, governor, nreq, out_len, arch):
    """Shared-system-prompt burst, cold cache vs ``prefix_cache=True``.

    Every request carries the same 96-token system prompt plus a short
    random tail — the chat/RAG traffic shape the prefix cache targets.  The
    warm run must produce bit-identical tokens (hard-asserted here: the CI
    smoke rides this scenario) while computing fewer prefill tokens and
    billing less prefill energy.  Accounting uses the *full-size* plant
    config for ``arch`` (virtual clock, deterministic): at paper scale the
    skipped tokens carry real joules, whereas the smoke model's prefill is
    weight-read-bound and nearly flat in L.

    Returns (warm tok/s, prefill_tokens_saved_frac, hit_rate,
    energy_per_request warm/cold ratio).
    """
    import dataclasses

    from repro.configs import get_config
    from repro.core import SamplingParams
    from repro.models import init_params
    from repro.serving import EngineConfig, Server, ServingEngine
    plant_cfg = get_config(arch)
    # f32 model compute: a hit routes the stream through chunked prefill
    # (reading matched context from the cache) while the cold run one-shots
    # the whole prompt — two summation orders that agree bitwise in f32 but
    # differ by an ulp in bf16, which the token-identity assert below would
    # trip over (same reason the paging equivalence tests pin f32).  Energy
    # accounting uses the plant config and is unaffected.
    if cfg.dtype != "float32":
        cfg = dataclasses.replace(cfg, dtype="float32")
        params = init_params(jax.random.PRNGKey(0), cfg)

    def run(pc):
        eng = ServingEngine(cfg, params=params, plant_cfg=plant_cfg,
                            ecfg=EngineConfig(
                                max_batch=8, max_len=256, governor=governor,
                                slot_native=True, paged=True,
                                cache_dtype="float32", prefix_cache=pc))
        srv = Server(eng)
        rng = np.random.default_rng(0)
        sys_prompt = rng.integers(0, cfg.vocab_size, size=96)
        for _ in range(nreq):
            tail = rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(8, 32)))
            srv.submit(np.concatenate([sys_prompt, tail]),
                       SamplingParams(max_tokens=out_len))
        t0 = time.perf_counter()
        rep = srv.run()
        jax.block_until_ready(eng._tok)
        return eng, rep, time.perf_counter() - t0

    run(True)                                  # compile warmup
    cold, crep, _ = run(False)
    warm, wrep, dt = run(True)
    assert [q.tokens for q in warm.requests] == \
        [q.tokens for q in cold.requests], \
        "prefix-cache hit must be token-identical to the cold run"
    assert crep.completed == wrep.completed == nreq
    saved = 1.0 - warm.prefill_tokens / cold.prefill_tokens
    hit_rate = warm.prefix_cache.stats()["hit_rate"]
    eratio = wrep.total_energy_j / crep.total_energy_j
    return nreq * out_len / dt, saved, hit_rate, eratio


def bench_metrics_overhead(cfg, params, *, batch, governor, nreq, out_len):
    """Serve the same burst with no observability sinks and with the full
    PR-7 surface installed (MetricsRegistry + Tracer through ``Server``).

    Hard-asserts the structural zero-overhead invariant first — identical
    host-drain counts, virtual clock and token totals between the two runs
    (observability must ride existing sync points, never add one) — then
    measures wall-clock overhead as median-of-3 per mode and asserts it
    stays under 2%.  Returns (plain tok/s, instrumented tok/s, registry).
    """
    from repro.core import MetricsRegistry, SamplingParams, Tracer
    from repro.serving import Server

    def run(with_sinks):
        eng = _engine(cfg, params, batch=batch, governor=governor,
                      slot_native=True)
        reg = MetricsRegistry(snapshot_min_dt=0.0) if with_sinks else None
        tr = Tracer() if with_sinks else None
        srv = Server(eng, metrics=reg, tracer=tr)
        rng = np.random.default_rng(0)
        for _ in range(nreq):
            srv.submit(rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(8, 100))),
                       SamplingParams(max_tokens=out_len))
        gc.collect()        # don't bill earlier runs' garbage to this one
        t0 = time.perf_counter()
        rep = srv.run()
        jax.block_until_ready(eng._tok)
        return time.perf_counter() - t0, eng, reg, rep

    run(False)                                 # compile warmup
    _, e0, _, r0 = run(False)
    _, e1, reg, r1 = run(True)
    assert e1._host_drains == e0._host_drains, \
        f"observability added host syncs: {e1._host_drains} vs " \
        f"{e0._host_drains}"
    assert abs(e1.vtime - e0.vtime) < 1e-9, "virtual clocks diverged"
    assert (r1.decode_tokens, r1.completed) == \
        (r0.decode_tokens, r0.completed), "served work diverged"
    # median of paired ratios: min-of-3 per mode let one lucky-fast bare
    # run inflate the ratio several percent on shared machines; pairing
    # adjacent bare/instrumented runs cancels slow load drift before the
    # ratio is taken.  A round poisoned end-to-end by external load defeats
    # any within-round statistic, so a failing round is re-measured once —
    # a real regression fails both rounds
    def measure():
        plains, insts = [], []
        for _ in range(5):
            plains.append(run(False)[0])
            insts.append(run(True)[0])
        return (statistics.median(i / p
                                  for p, i in zip(plains, insts)) - 1.0,
                statistics.median(plains), statistics.median(insts))

    overhead, t_plain, t_inst = measure()
    if overhead >= 0.02:
        overhead, t_plain, t_inst = measure()
    assert overhead < 0.02, \
        f"metrics/tracing overhead {overhead * 100:.2f}% exceeds 2% " \
        f"in two measurement rounds"
    total = nreq * out_len
    return total / t_plain, total / t_inst, reg


def bench_cluster(cfg, params, *, nreq, out_len, max_len=192):
    """Disaggregated 1 prefill + 1 decode cluster (GreenLLM per-phase DVFS)
    vs an equal-replica-count colocated max-frequency baseline on the same
    mini-trace: completed counts must match, and the energy ratio (incl.
    idle up to the shared makespan) is the headline number.

    Returns (tok/s of the disaggregated run, energy ratio disagg/colocated,
    handoffs, preemptions).
    """
    from repro.core import SamplingParams
    from repro.serving import EngineConfig, Server, ServingCluster

    def run(**kw):
        cl = ServingCluster(cfg, params=params, ecfg=EngineConfig(
            max_batch=8, max_len=max_len, governor=kw.pop("governor")), **kw)
        srv = Server(cl)
        rng = np.random.default_rng(0)
        for i in range(nreq):
            plen = int(rng.integers(24, max_len // 2))
            srv.submit(rng.integers(0, cfg.vocab_size, size=plen),
                       SamplingParams(max_tokens=out_len),
                       arrival=0.05 * i)
        t0 = time.perf_counter()
        rep = srv.run()
        return rep, time.perf_counter() - t0

    base, _ = run(governor="defaultnv", n_prefill=0, n_decode=0,
                  n_colocated=2)
    rep, dt = run(governor="greenllm", n_prefill=1, n_decode=1)
    assert rep.completed == base.completed == nreq
    tokens = rep.prefill_tokens + rep.decode_tokens
    return (tokens / dt, rep.total_energy_j / base.total_energy_j,
            rep.migrated, rep.preempted)


def bench_mesh(cfg, params, *, governor, nreq, out_len, mesh):
    """Same burst served unsharded and on a ``(dp, tp)`` device mesh.

    PR 10's equivalence bar makes this a parity gate, not a horse race:
    params are storage-sharded and gathered at kernel entry, slot rows and
    the paged pool shard along ``data`` — pure data movement, so tokens are
    hard-asserted identical and energy per token must sit inside
    ``compare.py``'s strict band (it is 1.0 exactly when the invariant
    holds).  Returns (mesh tok/s, energy-per-token ratio mesh/unsharded).
    """
    from repro.core import SamplingParams
    from repro.serving import EngineConfig, Server, ServingEngine

    def run(m):
        eng = ServingEngine(cfg, params=params, ecfg=EngineConfig(
            max_batch=8, max_len=256, governor=governor, slot_native=True,
            paged=True, mesh=m))
        srv = Server(eng)
        rng = np.random.default_rng(0)
        for _ in range(nreq):
            srv.submit(rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(8, 100))),
                       SamplingParams(max_tokens=out_len))
        t0 = time.perf_counter()
        rep = srv.run()
        jax.block_until_ready(eng._tok)
        return eng, rep, time.perf_counter() - t0

    run(mesh)                                  # compile warmup
    beng, brep, _ = run(None)
    meng, mrep, dt = run(mesh)
    assert [q.tokens for q in meng.requests] == \
        [q.tokens for q in beng.requests], \
        "mesh serving must be token-identical to the unsharded engine"
    assert mrep.completed == brep.completed == nreq

    def ept(rep):
        return rep.total_energy_j / (rep.prefill_tokens + rep.decode_tokens)

    return nreq * out_len / dt, ept(mrep) / ept(brep)


def _parse_mesh(spec: str):
    """'dp,tp' -> tuple; 'auto' picks the widest shape the visible devices
    support (both axes when 8 are forced, data-only on 2, degenerate on 1)."""
    if spec == "auto":
        d = len(jax.devices())
        return (2, 4) if d >= 8 else (2, 1) if d >= 2 else (1, 1)
    dp, tp = (int(x) for x in spec.split(","))
    return dp, tp


def bench_serving_engine(quick: bool = False, arch: str = "qwen2-1.5b",
                         batches=(1, 4, 8), governors=("greenllm", "defaultnv"),
                         paged: bool = False, cluster: bool = False,
                         prefix_cache: bool = False, mesh: str = "",
                         extras: dict = None):
    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config(arch).smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    steps = 48 if quick else 128
    nreq = 12 if quick else 24
    n_admit = 8 if quick else 16

    def warm2(fn, *a, **kw):
        # identical schedule -> identical (cfg, ctx, k) jit keys: the first
        # pass compiles into the shared cache, the second is the measurement
        fn(*a, **kw)
        return fn(*a, **kw)

    rows = []
    for gov in governors:
        dense_decode = {}
        for b in batches:
            legacy = bench_decode(cfg, params, batch=b, governor=gov,
                                  slot_native=False, steps=steps)
            slot = warm2(bench_decode, cfg, params, batch=b, governor=gov,
                         slot_native=True, steps=steps)
            dense_decode[b] = slot
            rows.append((f"engine_decode_b{b}_{gov}_legacy", 1e6 / legacy,
                         f"{legacy:.0f}tok/s"))
            rows.append((f"engine_decode_b{b}_{gov}_slot", 1e6 / slot,
                         f"{slot:.0f}tok/s;speedup={slot / legacy:.1f}x"))
        legacy = bench_admit(cfg, params, governor=gov, slot_native=False,
                             n=n_admit)
        slot = bench_admit(cfg, params, governor=gov, slot_native=True,
                           n=n_admit)
        rows.append((f"engine_admit_{gov}_legacy", 1e6 / legacy,
                     f"{legacy:.1f}adm/s"))
        rows.append((f"engine_admit_{gov}_slot", 1e6 / slot,
                     f"{slot:.1f}adm/s;speedup={slot / legacy:.1f}x"))
        b = max(batches)
        legacy = bench_serve(cfg, params, batch=b, governor=gov,
                             slot_native=False, nreq=nreq, out_len=32)
        slot = warm2(bench_serve, cfg, params, batch=b, governor=gov,
                     slot_native=True, nreq=nreq, out_len=32)
        rows.append((f"engine_serve_b{b}_{gov}_legacy", 1e6 / legacy,
                     f"{legacy:.0f}tok/s"))
        rows.append((f"engine_serve_b{b}_{gov}_slot", 1e6 / slot,
                     f"{slot:.0f}tok/s;speedup={slot / legacy:.1f}x"))
        mixed, gfrac = warm2(bench_mixed_sampling, cfg, params, batch=b,
                             governor=gov, nreq=nreq, out_len=32)
        rows.append((f"engine_serve_b{b}_{gov}_mixed_sampling", 1e6 / mixed,
                     f"{mixed:.0f}tok/s;vs_greedy={mixed / slot:.2f}x;"
                     f"greedy_frac={gfrac:.2f}"))
        if paged:
            rows.extend(_paged_rows(cfg, params, gov=gov, b=b, steps=steps,
                                    nreq=nreq, n_admit=n_admit, warm2=warm2,
                                    dense_decode=dense_decode[b]))
        if prefix_cache:
            tps, saved, hit, eratio = bench_prefix_cache(
                cfg, params, governor=gov, nreq=nreq,
                out_len=12 if quick else 24, arch=arch)
            rows.append((f"engine_prefix_cache_{gov}",
                         1e6 / max(tps, 1e-9),
                         f"{tps:.0f}tok/s;"
                         f"prefill_tokens_saved_frac={saved:.3f};"
                         f"hit_rate={hit:.2f};"
                         f"energy_per_req_vs_cold={eratio:.3f}x"))
    if governors:
        # observability overhead: no-sink vs instrumented serve (host-drain
        # and token equality hard-asserted; wall overhead must stay <2%)
        b = max(batches)
        plain, inst, reg = bench_metrics_overhead(
            cfg, params, batch=b, governor=governors[0], nreq=nreq,
            out_len=32)
        rows.append((f"engine_serve_b{b}_{governors[0]}_metrics",
                     1e6 / inst,
                     f"{inst:.0f}tok/s;overhead="
                     f"{(plain / inst - 1) * 100:.2f}%"))
        if extras is not None:
            extras["metrics_snapshot"] = reg.flat()
    if cluster:
        # 2-replica disaggregated mini-trace vs 2x-colocated max-freq
        tps, eratio, handoffs, preempted = bench_cluster(
            cfg, params, nreq=6 if quick else 12, out_len=12 if quick else 24)
        rows.append(("cluster_disagg_1p1d", 1e6 / max(tps, 1e-9),
                     f"{tps:.0f}tok/s;energy_vs_colocated="
                     f"{eratio:.2f}x;handoffs={handoffs};"
                     f"preempted={preempted}"))
    if mesh:
        # mesh-sharded data plane vs the unsharded engine on the same burst:
        # tokens hard-asserted identical, energy-per-token ratio gated by
        # compare.py's strict band (bit-exact serving makes it 1.0)
        m = _parse_mesh(mesh)
        gov = governors[0] if governors else "defaultnv"
        tps, eratio = bench_mesh(cfg, params, governor=gov,
                                 nreq=6 if quick else 12,
                                 out_len=12 if quick else 24, mesh=m)
        rows.append((f"engine_mesh_dp{m[0]}tp{m[1]}_{gov}",
                     1e6 / max(tps, 1e-9),
                     f"{tps:.0f}tok/s;"
                     f"energy_per_tok_vs_unsharded={eratio:.4f}x"))
    return rows


def _paged_rows(cfg, params, *, gov, b, steps, nreq, n_admit, warm2,
                dense_decode):
    """Paged-vs-dense and long-prompt-admission scenarios.  ``dense_decode``
    is the already-measured slot-native decode tok/s for this (gov, b)."""
    from repro.configs import get_config
    rows = []
    dense = dense_decode
    pg = warm2(bench_decode, cfg, params, batch=b, governor=gov,
               slot_native=True, steps=steps, paged=True)
    rows.append((f"engine_decode_b{b}_{gov}_paged", 1e6 / pg,
                 f"{pg:.0f}tok/s;vs_dense={pg / dense:.2f}x"))
    pg = warm2(bench_serve, cfg, params, batch=b, governor=gov,
               slot_native=True, nreq=nreq, out_len=32, paged=True)
    rows.append((f"engine_serve_b{b}_{gov}_paged", 1e6 / pg,
                 f"{pg:.0f}tok/s"))
    streams, dense_eq, tps = bench_paged_capacity(cfg, params, governor=gov,
                                                  nreq=b, out_len=16)
    rows.append((f"engine_capacity_{gov}_paged_halfmem", 1e6 / max(tps, 1e-9),
                 f"streams={streams};dense_equiv={dense_eq};{tps:.0f}tok/s"))
    # long-prompt chunked admission needs a sliding-window config
    wcfg = get_config("gemma2-9b").smoke()
    from repro.models import init_params as _ip
    wparams = _ip(jax.random.PRNGKey(0), wcfg)
    legacy = bench_long_admit(wcfg, wparams, governor=gov, n=n_admit,
                              chunked=False)
    chunked = bench_long_admit(wcfg, wparams, governor=gov, n=n_admit,
                               chunked=True)
    rows.append((f"engine_longadmit_{gov}_legacy", 1e6 / legacy,
                 f"{legacy:.1f}adm/s"))
    rows.append((f"engine_longadmit_{gov}_chunked", 1e6 / chunked,
                 f"{chunked:.1f}adm/s;speedup={chunked / legacy:.1f}x"))
    return rows


def bench_serving_engine_quick():
    """Registry entry for benchmarks.run (CI-sized)."""
    return bench_serving_engine(quick=True, batches=(1, 8),
                                governors=("defaultnv",), paged=True,
                                prefix_cache=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="add paged-vs-dense, capacity and long-prompt-"
                         "admission scenarios")
    ap.add_argument("--cluster", action="store_true",
                    help="add the 2-replica disaggregated prefill/decode "
                         "mini-trace vs the 2x-colocated max-freq baseline")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="add the shared-system-prompt burst: prefix cache "
                         "vs cold cache (prefill tokens computed, hit rate, "
                         "energy/request; token identity hard-asserted)")
    ap.add_argument("--mesh", default="", metavar="DP,TP",
                    help="add the mesh-sharded serving scenario on a "
                         "'dp,tp' device mesh ('auto' sizes to the visible "
                         "devices; force CPU devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8): tokens "
                         "hard-asserted identical to the unsharded engine, "
                         "energy-per-token parity gated by compare.py")
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batches", default="1,4,8")
    ap.add_argument("--governors", default="greenllm,defaultnv")
    ap.add_argument("--json", default="", metavar="OUT",
                    help="also write rows + run config as a JSON document "
                         "(the BENCH_*.json baseline format)")
    args = ap.parse_args()
    batches = tuple(int(x) for x in args.batches.split(","))
    # --governors "" runs only the standalone scenarios (e.g. --cluster)
    governors = tuple(g for g in args.governors.split(",") if g)
    extras = {}
    rows = bench_serving_engine(
        quick=args.quick, arch=args.arch, batches=batches,
        governors=governors, paged=args.paged, cluster=args.cluster,
        prefix_cache=args.prefix_cache, mesh=args.mesh, extras=extras)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}", flush=True)
    if args.json:
        doc = {
            "benchmark": "serving_engine",
            "config": {"quick": args.quick, "arch": args.arch,
                       "batches": list(batches),
                       "governors": list(governors),
                       "paged": args.paged, "cluster": args.cluster,
                       "prefix_cache": args.prefix_cache,
                       "mesh": args.mesh},
            "backend": jax.default_backend(),
            "rows": [{"name": n, "us_per_call": round(us, 1),
                      "derived": d} for n, us, d in rows],
            # final registry state of the instrumented serve run: makes the
            # baseline diffable on served work, not just wall time
            **({"metrics_snapshot": extras["metrics_snapshot"]}
               if "metrics_snapshot" in extras else {}),
        }
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=2)
            fh.write("\n")
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
