"""Serving-engine data-plane benchmark: slot-native vs the pre-PR (legacy)
engine, wall-clock measured on the smoke config.

Three metrics per (governor, batch):

* ``decode``  — steady-state decode tokens/s with a full batch of
  never-ending streams (no admissions in the window): isolates the jitted
  block decode (ctx-bucketed, scanned, donated, no per-token host sync)
  against the legacy per-step host-synced loop.
* ``admit``   — admissions/s: jitted bucketed slot prefill vs the legacy
  eager prefill + fresh per-request cache + host-side full-batch splice.
* ``serve``   — sustained serving tokens/s with continuous batching churn
  (finite outputs, streams join/leave): the end-to-end engine number.

    PYTHONPATH=src python benchmarks/serving_engine.py [--quick]
        [--arch qwen2-1.5b] [--batches 1,4,8] [--governors greenllm,defaultnv]

Prints ``name,value,derived`` CSV rows like benchmarks/run.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def _engine(cfg, params, *, batch, governor, slot_native, max_len=256):
    from repro.serving import EngineConfig, ServingEngine
    return ServingEngine(cfg, params=params, ecfg=EngineConfig(
        max_batch=batch, max_len=max_len, governor=governor,
        slot_native=slot_native))


def _fill(eng, batch, *, prompt_len=24, output_len=10 ** 9, rng=None):
    from repro.core import Request
    for i in range(batch):
        pl = prompt_len if rng is None else int(rng.integers(8, 100))
        eng.submit(Request(rid=i, arrival=0.0, prompt_len=pl,
                           output_len=output_len))
    eng._admit()


def bench_decode(cfg, params, *, batch, governor, slot_native, steps):
    eng = _engine(cfg, params, batch=batch, governor=governor,
                  slot_native=slot_native)
    _fill(eng, batch)
    # warm the (ctx, k) kernels outside the timed window
    for _ in range(2):
        eng._decode_block(16) if slot_native else eng._step_legacy()
    jax.block_until_ready(eng._tok)
    t0 = time.perf_counter()
    if slot_native:
        eng._decode_block(steps)
    else:
        for _ in range(steps):
            eng._step_legacy()
    jax.block_until_ready(eng.caches)
    return batch * steps / (time.perf_counter() - t0)


def bench_admit(cfg, params, *, governor, slot_native, n):
    eng = _engine(cfg, params, batch=8, governor=governor,
                  slot_native=slot_native)
    from repro.core import Request
    eng.submit(Request(rid=10 ** 6, arrival=0.0, prompt_len=24, output_len=4))
    eng._admit()                       # compile warmup
    eng._retire(list(eng.active.keys()))
    jax.block_until_ready(eng._tok)
    for i in range(n):
        eng.submit(Request(rid=i, arrival=0.0, prompt_len=24, output_len=4))
    t0 = time.perf_counter()
    while eng.pending:
        eng._admit()
        jax.block_until_ready(eng._tok)
        eng._retire(list(eng.active.keys()))
    return n / (time.perf_counter() - t0)


def bench_serve(cfg, params, *, batch, governor, slot_native, nreq, out_len):
    eng = _engine(cfg, params, batch=batch, governor=governor,
                  slot_native=slot_native)
    rng = np.random.default_rng(0)
    _fill(eng, nreq, output_len=out_len, rng=rng)
    t0 = time.perf_counter()
    eng.run_until_drained()
    jax.block_until_ready(eng._tok)
    return nreq * out_len / (time.perf_counter() - t0)


def bench_serving_engine(quick: bool = False, arch: str = "qwen2-1.5b",
                         batches=(1, 4, 8), governors=("greenllm", "defaultnv")):
    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config(arch).smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    steps = 48 if quick else 128
    nreq = 12 if quick else 24
    n_admit = 8 if quick else 16

    def warm2(fn, *a, **kw):
        # identical schedule -> identical (cfg, ctx, k) jit keys: the first
        # pass compiles into the shared cache, the second is the measurement
        fn(*a, **kw)
        return fn(*a, **kw)

    rows = []
    for gov in governors:
        for b in batches:
            legacy = bench_decode(cfg, params, batch=b, governor=gov,
                                  slot_native=False, steps=steps)
            slot = warm2(bench_decode, cfg, params, batch=b, governor=gov,
                         slot_native=True, steps=steps)
            rows.append((f"engine_decode_b{b}_{gov}_legacy", 1e6 / legacy,
                         f"{legacy:.0f}tok/s"))
            rows.append((f"engine_decode_b{b}_{gov}_slot", 1e6 / slot,
                         f"{slot:.0f}tok/s;speedup={slot / legacy:.1f}x"))
        legacy = bench_admit(cfg, params, governor=gov, slot_native=False,
                             n=n_admit)
        slot = bench_admit(cfg, params, governor=gov, slot_native=True,
                           n=n_admit)
        rows.append((f"engine_admit_{gov}_legacy", 1e6 / legacy,
                     f"{legacy:.1f}adm/s"))
        rows.append((f"engine_admit_{gov}_slot", 1e6 / slot,
                     f"{slot:.1f}adm/s;speedup={slot / legacy:.1f}x"))
        b = max(batches)
        legacy = bench_serve(cfg, params, batch=b, governor=gov,
                             slot_native=False, nreq=nreq, out_len=32)
        slot = warm2(bench_serve, cfg, params, batch=b, governor=gov,
                     slot_native=True, nreq=nreq, out_len=32)
        rows.append((f"engine_serve_b{b}_{gov}_legacy", 1e6 / legacy,
                     f"{legacy:.0f}tok/s"))
        rows.append((f"engine_serve_b{b}_{gov}_slot", 1e6 / slot,
                     f"{slot:.0f}tok/s;speedup={slot / legacy:.1f}x"))
    return rows


def bench_serving_engine_quick():
    """Registry entry for benchmarks.run (CI-sized)."""
    return bench_serving_engine(quick=True, batches=(1, 8),
                                governors=("defaultnv",))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batches", default="1,4,8")
    ap.add_argument("--governors", default="greenllm,defaultnv")
    args = ap.parse_args()
    batches = tuple(int(x) for x in args.batches.split(","))
    governors = tuple(args.governors.split(","))
    print("name,us_per_call,derived")
    for name, us, derived in bench_serving_engine(
            quick=args.quick, arch=args.arch, batches=batches,
            governors=governors):
        print(f"{name},{us:.0f},{derived}", flush=True)


if __name__ == "__main__":
    main()
