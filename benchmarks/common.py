"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import numpy as np

from repro.configs import get_config
from repro.core import DualLoopController, MaxFreqController
from repro.core.hardware import A100_SXM4_40G
from repro.sim import PlantModel, profile_decode_table

HW = A100_SXM4_40G
Row = Tuple[str, float, str]


def timed(fn: Callable):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def make_decode_controller(cfg_name: str, tbt_slo=0.100, seed=8):
    plant = PlantModel(cfg=get_config(cfg_name), hw=HW, n_chips=1,
                       noise_sigma=0.0, seed=seed)
    table = profile_decode_table(plant, tbt_slo)
    return DualLoopController(HW, table)


def run_decode_bench(cfg_name: str, controller, tps_fn, duration: float,
                     ctx: int = 640, seed: int = 9):
    """Single decode worker driven at a target aggregate TPS; concurrency is
    adjusted each step to hold the target (paper's decode microbenchmark)."""
    plant = PlantModel(cfg=get_config(cfg_name), hw=HW, n_chips=1,
                       noise_sigma=0.01, seed=seed)
    t, energy, tokens = 0.0, 0.0, 0
    last = 0.03
    tbts: List[float] = []
    freqs: List[Tuple[float, float, float]] = []
    while t < duration:
        f = controller.maybe_tick(t)
        tps = max(tps_fn(t), 1.0)
        batch = int(np.clip(np.ceil(tps * last), 1, 512))
        dur = plant.decode_step_latency(batch, ctx, f)
        power = plant.decode_power(batch, ctx, f, dur)
        energy += power * dur
        tokens += batch
        controller.record_tokens(t + dur, batch, dur)
        tbts.append(dur)
        freqs.append((t, f, tps))
        last = dur
        t += dur
    return {"energy_j": energy, "tokens": tokens,
            "tbt_p90": float(np.percentile(tbts, 90)),
            "tbt_p95": float(np.percentile(tbts, 95)),
            "tbt_p99": float(np.percentile(tbts, 99)),
            "freqs": freqs}
