"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
results/dryrun artifacts.

    PYTHONPATH=src python -m benchmarks.report > results/roofline_report.md
"""
from __future__ import annotations

import glob
import json
import os

from .roofline import load_records, roofline_row


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(recs, mesh):
    print(f"\n### Dry-run — mesh {mesh}\n")
    print("| arch | shape | compile s | peak GiB/dev | HLO GFLOP/dev | "
          "collective MiB/dev | coll ops (ag/ar/rs/a2a/cp) |")
    print("|---|---|---:|---:|---:|---:|---|")
    for r in recs:
        if r["mesh"] != mesh or r.get("variant", {}).get("tag"):
            continue
        c = r["collectives"]
        ops = "/".join(str(c[k]["count"]) for k in
                       ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"))
        ce = r.get("cost_extrapolated", {})
        print(f"| {r['arch']} | {r['shape']} | {r['compile_s']:.1f} "
              f"| {fmt_bytes(r['memory']['peak_bytes_per_device'])} "
              f"| {ce.get('flops', 0)/1e9:.0f} "
              f"| {ce.get('coll_bytes', 0)/2**20:.0f} | {ops} |")


def roofline_table(recs):
    print("\n### Roofline — single pod (16x16, TPU v5e constants)\n")
    print("| arch | shape | compute ms | memory ms | collective ms | "
          "dominant | MODEL/HLO flops | peak GiB |")
    print("|---|---|---:|---:|---:|---|---:|---:|")
    for rec in recs:
        if rec["mesh"] != "16x16" or rec.get("variant", {}).get("tag"):
            continue
        r = roofline_row(rec)
        print(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} "
              f"| {r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} "
              f"| {r['dominant']} | {r['useful_ratio']:.2f} "
              f"| {r['peak_gib']:.2f} |")


def main():
    recs = sorted(load_records(), key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    dryrun_table(recs, "16x16")
    dryrun_table(recs, "2x16x16")
    roofline_table(recs)
    # perf-variant records
    tagged = [r for r in recs if r.get("variant", {}).get("tag")]
    if tagged:
        print("\n### Perf variants\n")
        print("| tag | arch | shape | compute ms | memory ms | coll ms | peak GiB |")
        print("|---|---|---|---:|---:|---:|---:|")
        for rec in tagged:
            r = roofline_row(rec)
            print(f"| {rec['variant']['tag']} | {r['arch']} | {r['shape']} "
                  f"| {r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} "
                  f"| {r['t_collective_s']*1e3:.2f} | {r['peak_gib']:.2f} |")


if __name__ == "__main__":
    main()
