"""One benchmark per paper figure/table (deliverable d)."""
from __future__ import annotations

import copy
from typing import List

import numpy as np

from repro.configs import get_config
from repro.core import MaxFreqController, SLOConfig
from repro.data import get_trace, sinusoidal_decode_load
from repro.sim import (PlantModel, ReplayConfig, profile_power,
                       profile_prefill_latency, replay)
from .common import HW, Row, make_decode_controller, run_decode_bench, timed


# -- Fig. 1: sinusoidal tracking -------------------------------------------------------

def bench_fig1_sinusoid() -> List[Row]:
    _, tps = sinusoidal_decode_load()
    tps_fn = lambda t: float(np.interp(t % 120.0, np.arange(0, 120, 0.5), tps))
    def run():
        green = run_decode_bench("qwen3-14b", make_decode_controller("qwen3-14b"),
                                 tps_fn, 120.0)
        base = run_decode_bench("qwen3-14b", MaxFreqController(HW), tps_fn, 120.0)
        return green, base
    (green, base), us = timed(run)
    g_f = np.asarray([f for _, f, _ in green["freqs"]])
    saving = 1 - green["energy_j"] / base["energy_j"]
    rows = [
        ("fig1_sinusoid/freq_range_mhz", us, f"{g_f.min():.0f}-{g_f.max():.0f}"),
        ("fig1_sinusoid/p99_tbt_green_ms", us, f"{green['tbt_p99']*1e3:.1f}"),
        ("fig1_sinusoid/p99_tbt_default_ms", us, f"{base['tbt_p99']*1e3:.1f}"),
        ("fig1_sinusoid/decode_energy_saving", us, f"{saving:.3f}"),
    ]
    return rows


# -- Fig. 3: U-shaped energy curves -------------------------------------------------------

def bench_fig3_energy_curves() -> List[Row]:
    cfg = get_config("qwen3-14b")
    rows = []
    def run():
        out = {}
        # (a) prefill energy vs f
        plant = PlantModel(cfg=cfg, hw=HW, n_chips=2, noise_sigma=0.0)
        ladder = HW.ladder()
        E = []
        for f in ladder:
            t = plant.prefill_latency(1024, f)
            E.append(plant.prefill_power(1024, f, t) * t)
        E = np.asarray(E)
        out["prefill_knee"] = ladder[int(np.argmin(E))] / HW.f_max
        out["prefill_u"] = (E[0] > E.min()) and (E[-1] > E.min())
        # (b) decode energy/token vs f
        Ed = []
        dp = PlantModel(cfg=cfg, hw=HW, n_chips=1, noise_sigma=0.0)
        for f in ladder:
            t = dp.decode_step_latency(64, 1000, f)
            Ed.append(dp.decode_power(64, 1000, f, t) * t / 64)
        Ed = np.asarray(Ed)
        out["decode_knee"] = ladder[int(np.argmin(Ed))] / HW.f_max
        out["decode_u"] = (Ed[0] > Ed.min()) and (Ed[-1] > Ed.min())
        # (c) fixed-frequency trace sweep
        trace = get_trace("chat_8qps", duration=60)
        Es = {}
        for f in (HW.f_min, 660.0, 900.0, 1140.0, HW.f_max):
            m = replay(cfg, trace, ReplayConfig(governor="fixed", fixed_freq=f))
            Es[f] = m.total_energy_j
        best = min(Es, key=Es.get)
        out["trace_opt_f"] = best / HW.f_max
        out["trace_saving_vs_fmax"] = 1 - Es[best] / Es[HW.f_max]
        return out
    out, us = timed(run)
    return [
        ("fig3a_prefill_knee_frac", us, f"{out['prefill_knee']:.2f}"),
        ("fig3a_prefill_is_u", us, str(out["prefill_u"])),
        ("fig3b_decode_knee_frac", us, f"{out['decode_knee']:.2f}"),
        ("fig3b_decode_knee_below_prefill", us,
         str(out["decode_knee"] <= out["prefill_knee"])),
        ("fig3c_trace_opt_f_frac", us, f"{out['trace_opt_f']:.2f}"),
        ("fig3c_saving_vs_fmax", us, f"{out['trace_saving_vs_fmax']:.3f}"),
    ]


# -- Fig. 5: routing TTFT distribution ------------------------------------------------------

def bench_fig5_routing() -> List[Row]:
    cfg = get_config("qwen3-14b")
    trace = get_trace("chat_8qps", duration=90)
    def run():
        base = replay(cfg, trace, ReplayConfig(governor="defaultNV"))
        split = replay(cfg, trace, ReplayConfig(governor="prefillsplit"))
        return base, split
    (base, split), us = timed(run)
    return [
        ("fig5_slo_pass_default", us, f"{base.ttft_pass:.3f}"),
        ("fig5_slo_pass_routed", us, f"{split.ttft_pass:.3f}"),
        ("fig5_p90_ttft_sm_default_s", us, f"{base.p90_ttft.get('SM', 0):.3f}"),
        ("fig5_p90_ttft_sm_routed_s", us, f"{split.p90_ttft.get('SM', 0):.3f}"),
    ]


# -- Fig. 7/8: fit quality --------------------------------------------------------------------

def bench_fig7_latency_fit() -> List[Row]:
    plant = PlantModel(cfg=get_config("qwen3-14b"), hw=HW, n_chips=2,
                       noise_sigma=0.01, seed=5)
    def run():
        m = profile_prefill_latency(plant)
        L = np.linspace(64, 8192, 30)
        t = [plant.prefill_latency(int(x), HW.f_max) for x in L]
        return m, m.r2(L, t)
    (m, r2), us = timed(run)
    return [("fig7_quadratic_fit_r2", us, f"{r2:.4f}"),
            ("fig7_coeff_a", us, f"{m.a:.3e}")]


def bench_fig8_power_fit() -> List[Row]:
    plant = PlantModel(cfg=get_config("qwen3-14b"), hw=HW, n_chips=2,
                       noise_sigma=0.01, seed=6)
    def run():
        m = profile_power(plant)
        f = HW.ladder()
        meas = []
        for x in f:
            t = plant.prefill_latency(1024, x)
            meas.append(plant.prefill_power(1024, x, t) / plant.n_chips)
        pred = m.predict(f)
        ss = 1 - np.sum((pred - meas) ** 2) / np.sum((meas - np.mean(meas)) ** 2)
        return m, ss
    (m, r2), us = timed(run)
    return [("fig8_cubic_power_fit_r2", us, f"{r2:.4f}")]


# -- Fig. 10: prefill TTFT/energy vs load -------------------------------------------------------

def bench_fig10_prefill() -> List[Row]:
    cfg = get_config("qwen3-14b")
    rows = []
    def run():
        out = []
        for qps in (2, 5, 8):
            trace = get_trace(f"chat_{qps}qps", duration=60)
            base = replay(cfg, trace, ReplayConfig(governor="defaultNV"))
            green = replay(cfg, trace, ReplayConfig(governor="greenllm"))
            out.append((qps, base, green))
        return out
    out, us = timed(run)
    for qps, base, green in out:
        saving = 1 - green.prefill_energy_j / base.prefill_energy_j
        rows.append((f"fig10_prefill_saving_{qps}qps", us, f"{saving:.3f}"))
        rows.append((f"fig10_ttft_pass_green_{qps}qps", us,
                     f"{green.ttft_pass:.3f}"))
    return rows


# -- Fig. 11: decode TBT/energy vs TPS ------------------------------------------------------------

def bench_fig11_decode() -> List[Row]:
    rows = []
    def run():
        out = []
        for tps in (200, 1000, 3000):
            green = run_decode_bench("qwen3-14b",
                                     make_decode_controller("qwen3-14b"),
                                     lambda t, v=tps: v, 45.0)
            base = run_decode_bench("qwen3-14b", MaxFreqController(HW),
                                    lambda t, v=tps: v, 45.0)
            out.append((tps, green, base))
        return out
    out, us = timed(run)
    for tps, green, base in out:
        saving = 1 - green["energy_j"] / base["energy_j"]
        rows.append((f"fig11_decode_saving_{tps}tps", us, f"{saving:.3f}"))
        rows.append((f"fig11_p90_tbt_green_{tps}tps_ms", us,
                     f"{green['tbt_p90']*1e3:.1f}"))
    return rows


# -- Tables 3-4: trace grid -----------------------------------------------------------------------

def _table_rows(prefix: str, model: str, traces, duration=90.0) -> List[Row]:
    cfg = get_config(model)
    rows = []
    def run():
        out = []
        for tr in traces:
            trace = get_trace(tr, duration=duration)
            base = replay(cfg, trace, ReplayConfig(governor="defaultNV"))
            split = replay(cfg, trace, ReplayConfig(governor="prefillsplit"))
            green = replay(cfg, trace, ReplayConfig(governor="greenllm"))
            out.append((tr, base, split, green))
        return out
    out, us = timed(run)
    for tr, base, split, green in out:
        dE = 1 - green.total_energy_j / base.total_energy_j
        rel_dec = green.decode_energy_j / base.decode_energy_j
        rel_pre = green.prefill_energy_j / base.prefill_energy_j
        rows.append((f"{prefix}_{tr}_dE", us, f"{dE:.4f}"))
        rows.append((f"{prefix}_{tr}_rel_decode", us, f"{rel_dec:.3f}"))
        rows.append((f"{prefix}_{tr}_rel_prefill", us, f"{rel_pre:.3f}"))
        rows.append((f"{prefix}_{tr}_ttft_pct", us, f"{green.ttft_pass:.3f}"))
        rows.append((f"{prefix}_{tr}_tbt_pct", us, f"{green.tbt_pass:.3f}"))
        rows.append((f"{prefix}_{tr}_split_dE", us,
                     f"{1 - split.total_energy_j / base.total_energy_j:.4f}"))
    return rows


def bench_table3_qwen14b() -> List[Row]:
    return _table_rows("table3", "qwen3-14b",
                       ["chat_1qps", "chat_3qps", "chat_5qps", "chat_8qps",
                        "chat_10qps", "azure_code5", "azure_code8",
                        "azure_conv5", "azure_conv8"])


def bench_table4_qwen30b_moe() -> List[Row]:
    return _table_rows("table4", "qwen3-moe-30b-a3b",
                       ["chat_1qps", "chat_3qps", "chat_5qps",
                        "azure_code5", "azure_code8", "azure_conv5",
                        "azure_conv8"])


# -- Fig. 12: margin sensitivity ---------------------------------------------------------------------

def bench_fig12_margin() -> List[Row]:
    cfg = get_config("qwen3-14b")
    trace = get_trace("chat_5qps", duration=60)
    rows = []
    def run():
        out = {}
        for m in (0.6, 0.95, 1.2, 2.0):
            r = replay(cfg, trace, ReplayConfig(
                governor="greenllm",
                slo=SLOConfig(prefill_margin=m, decode_margin=0.95)))
            out[("prefill", m)] = r
        for m in (0.6, 0.95, 1.2, 2.0):
            r = replay(cfg, trace, ReplayConfig(
                governor="greenllm",
                slo=SLOConfig(prefill_margin=0.95, decode_margin=m)))
            out[("decode", m)] = r
        return out
    out, us = timed(run)
    for (phase, m), r in out.items():
        e = r.prefill_energy_j if phase == "prefill" else r.decode_energy_j
        lat = (r.p90_ttft.get("SM", 0) if phase == "prefill" else r.p95_tbt)
        rows.append((f"fig12_{phase}_margin{m}_energy_kj", us, f"{e/1e3:.1f}"))
        rows.append((f"fig12_{phase}_margin{m}_lat_s", us, f"{lat:.3f}"))
    return rows


ALL_BENCHES = [
    bench_fig1_sinusoid,
    bench_fig3_energy_curves,
    bench_fig5_routing,
    bench_fig7_latency_fit,
    bench_fig8_power_fit,
    bench_fig10_prefill,
    bench_fig11_decode,
    bench_table3_qwen14b,
    bench_table4_qwen30b_moe,
    bench_fig12_margin,
]
