"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Usage:

    PYTHONPATH=src python -m benchmarks.run [--only substr] [--skip-roofline]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    from .paper_figs import ALL_BENCHES
    from .roofline import bench_roofline
    from .serving_engine import bench_serving_engine_quick

    benches = list(ALL_BENCHES)
    benches.append(bench_serving_engine_quick)
    if not args.skip_roofline:
        benches.append(bench_roofline)

    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        if args.only and args.only not in bench.__name__:
            continue
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.0f},{derived}", flush=True)
        except Exception:
            failures += 1
            print(f"{bench.__name__},-1,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
