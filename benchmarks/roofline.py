"""§Roofline: derive compute/memory/collective terms per (arch x shape x
mesh) from the dry-run artifacts in results/dryrun/ (deliverable g).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

HLO terms use the loop-trip-corrected per-device extrapolation recorded by
the dry-run (XLA's cost analysis counts while bodies once); since they are
already per-device, the chip division is implicit.  Hardware constants:
TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops(rec: Dict) -> float:
    """MODEL_FLOPS = 6 N D (train) or 2 N_active D (single forward)."""
    shape = rec["shape"]
    n_active = rec["model"]["params_active"]
    if rec["kind"] == "train":
        tokens = {"train_4k": 256 * 4096}[shape]
        return 6.0 * n_active * tokens
    if rec["kind"] == "prefill":
        tokens = 32 * 32768
        return 2.0 * n_active * tokens
    tokens = {"decode_32k": 128, "long_500k": 1}[shape]
    return 2.0 * n_active * tokens


def load_records(root: str = "results/dryrun") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(root, "*", "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def analytic_hbm_bytes(rec: Dict) -> float:
    """Per-device HBM traffic estimate.

    XLA's 'bytes accessed' counts every operand at every HLO op (no on-chip /
    VMEM reuse), over-stating real HBM traffic by >10x, so the memory term
    uses this analytic model instead: weight reads, optimizer state traffic,
    activation read/write per layer, and KV/state reads — each sharded the
    way the dry-run shards them.  The HLO figure is kept in the JSON as a
    diagnostic upper bound.
    """
    from repro.configs import get_config
    cfg = get_config(rec["arch"])
    it = 2  # bf16
    n_model = 16
    pod = 2 if rec["mesh"] == "2x16x16" else 1
    n_chips = rec["n_chips"]
    n_batch = n_chips // n_model
    W = cfg.param_count(active_only=True) * it / n_model   # per device
    L, d = cfg.num_layers, cfg.d_model
    ACT_C = 24  # bytes-per-token activation traffic multiplier per layer

    shape = rec["shape"]
    if shape == "train_4k":
        tokens_dev = 256 * 4096 / n_batch
        mb = rec.get("meta", {}).get("microbatches", 1) or 1
        act = tokens_dev * d * L * ACT_C * it * 3       # fwd + remat + bwd
        opt = cfg.param_count() * 4 * 4 / n_chips       # m,v read+write (ZeRO)
        wts = 3 * W * mb                                # re-read per microbatch
        return wts + act + opt
    if shape == "prefill_32k":
        tokens_dev = 32 * 32768 / n_batch
        act = tokens_dev * d * L * ACT_C * it
        kv_write = tokens_dev * cfg.decode_bytes_per_token(0, batch=10 ** 9)
        return W + act + kv_write
    # decode: weights + full cache read for the per-device streams
    batch = {"decode_32k": 128, "long_500k": 1}[shape]
    seq = {"decode_32k": 32768, "long_500k": 524288}[shape]
    batch_dev = max(batch / n_batch, batch / n_chips if batch == 1 else 1)
    state_per_stream = cfg.decode_bytes_per_token(seq, batch=10 ** 9)
    if rec.get("variant", {}).get("kv_quant"):
        # int8 KV + f32 per-(token, head) scales
        state_per_stream *= 0.5 + 2.0 / cfg.head_dim
    if batch == 1:
        state_dev = state_per_stream / (n_chips / pod)   # seq-sharded cache
    else:
        state_dev = batch_dev * state_per_stream / n_model
    return W + state_dev


def roofline_row(rec: Dict) -> Dict:
    n = rec["n_chips"]
    ce = rec.get("cost_extrapolated", {})
    flops = max(ce.get("flops", rec["cost"]["flops_per_device"]), 0.0)
    bytes_ = analytic_hbm_bytes(rec)
    # depth-diff extrapolation can go slightly negative on fusion variance
    coll = max(ce.get("coll_bytes", rec["collectives"]["total_bytes"]), 0.0)
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_ / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_total = flops * n
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "peak_gib": rec["memory"]["peak_bytes_per_device"] / 2 ** 30,
    }


def run(root: str = "results/dryrun") -> List[Dict]:
    return [roofline_row(r) for r in load_records(root)]


def run_baselines(root: str = "results/dryrun") -> List[Dict]:
    return [roofline_row(r) for r in load_records(root)
            if not r.get("variant", {}).get("tag")]


def bench_roofline():
    rows = []
    for r in run():
        name = f"roofline_{r['mesh']}_{r['arch']}_{r['shape']}"
        derived = (f"comp={r['t_compute_s']*1e3:.2f}ms|"
                   f"mem={r['t_memory_s']*1e3:.2f}ms|"
                   f"coll={r['t_collective_s']*1e3:.2f}ms|"
                   f"dom={r['dominant']}|useful={r['useful_ratio']:.2f}")
        rows.append((name, 0.0, derived))
    return rows


def print_table(root: str = "results/dryrun"):
    rows = [roofline_row(r) for r in load_records(root)
            if not r.get("variant", {}).get("tag")]
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'comp(ms)':>9s} "
           f"{'mem(ms)':>9s} {'coll(ms)':>9s} {'dom':>10s} {'useful':>7s} "
           f"{'GiB':>6s}")
    print(hdr)
    for r in sorted(rows, key=lambda x: (x["mesh"], x["arch"], x["shape"])):
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['t_compute_s']*1e3:9.2f} {r['t_memory_s']*1e3:9.2f} "
              f"{r['t_collective_s']*1e3:9.2f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.2f} {r['peak_gib']:6.2f}")


if __name__ == "__main__":
    print_table()
