"""Benchmark regression checker: diff a fresh ``serving_engine.py --json``
run against the checked-in baseline.

Usage::

    PYTHONPATH=src python benchmarks/serving_engine.py --quick \
        --batches 1,8 --governors defaultnv --json /tmp/fresh.json
    python benchmarks/compare.py --fresh /tmp/fresh.json \
        [--baseline benchmarks/BENCH_serving_engine.json] \
        [--tol 0.10] [--energy-tol 0.10]

Two gates, both relative to the baseline:

* **throughput** — every row name present in both files compares
  ``us_per_call``; a slowdown beyond ``--tol`` fails.  Timing rows are
  noisy on shared CI runners, so CI invokes this with a wide ``--tol``
  while keeping the energy gate strict.
* **energy per token** — derived from the ``metrics_snapshot`` the
  benchmark's metrics scenario embeds ((prefill + decode joules) /
  (prefill + decode tokens)).  This is virtual-clock accounting, fully
  deterministic, so ``--energy-tol`` stays at 10%: a regression here
  means the serving engine actually bills more energy for the same
  work, not that the runner was busy.

Rows missing on either side are reported and skipped (benchmarks gain
scenarios over time); exit status is 1 iff any gate fails.
"""
import argparse
import json
import math
import sys


def _energy_per_token(snap):
    e = sum(v for k, v in snap.items()
            if k.startswith("greenllm_energy_joules_total")
            and ('phase="prefill"' in k or 'phase="decode"' in k))
    t = sum(v for k, v in snap.items()
            if k.startswith("greenllm_tokens_total"))
    return e / t if t else math.nan


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline",
                    default="benchmarks/BENCH_serving_engine.json")
    ap.add_argument("--fresh", required=True,
                    help="--json output of a fresh benchmark run")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="max relative us_per_call slowdown per row")
    ap.add_argument("--energy-tol", type=float, default=0.10,
                    help="max relative energy-per-token increase")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    base_rows = {r["name"]: float(r["us_per_call"])
                 for r in base.get("rows", [])}
    fresh_rows = {r["name"]: float(r["us_per_call"])
                  for r in fresh.get("rows", [])}

    failures = []
    compared = 0
    for name in sorted(base_rows):
        if name not in fresh_rows:
            print(f"skip {name}: not in fresh run")
            continue
        b, fr = base_rows[name], fresh_rows[name]
        ratio = (fr - b) / b
        bad = ratio > args.tol
        print(f"{'FAIL' if bad else '  ok'} {name}: "
              f"{b:.1f} -> {fr:.1f} us/call ({ratio:+.1%})")
        if bad:
            failures.append(f"{name} slowed {ratio:+.1%} (tol {args.tol:.0%})")
        compared += 1
    for name in sorted(set(fresh_rows) - set(base_rows)):
        print(f"new  {name}: {fresh_rows[name]:.1f} us/call (no baseline)")

    bs = base.get("metrics_snapshot")
    fs = fresh.get("metrics_snapshot")
    if bs and fs:
        eb, ef = _energy_per_token(bs), _energy_per_token(fs)
        ratio = (ef - eb) / eb
        bad = ratio > args.energy_tol
        print(f"{'FAIL' if bad else '  ok'} energy_per_token: "
              f"{eb * 1e3:.4f} -> {ef * 1e3:.4f} mJ/tok ({ratio:+.1%})")
        if bad:
            failures.append(f"energy per token rose {ratio:+.1%} "
                            f"(tol {args.energy_tol:.0%})")
    else:
        print("skip energy_per_token: metrics_snapshot missing on "
              f"{'baseline' if not bs else 'fresh'} side")

    if not compared:
        failures.append("no common rows between baseline and fresh run")
    if failures:
        print(f"\n{len(failures)} regression(s):", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print(f"\nall gates passed ({compared} rows compared)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
