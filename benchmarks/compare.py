"""Benchmark regression checker: diff a fresh ``serving_engine.py --json``
run against the checked-in baseline.

Usage::

    PYTHONPATH=src python benchmarks/serving_engine.py --quick \
        --batches 1,8 --governors defaultnv --json /tmp/fresh.json
    python benchmarks/compare.py --fresh /tmp/fresh.json \
        [--baseline benchmarks/BENCH_serving_engine.json] \
        [--tol 0.10] [--energy-tol 0.10]

Two gates, both relative to the baseline:

* **throughput** — every row name present in both files compares
  ``us_per_call``; a slowdown beyond ``--tol`` fails.  Timing rows are
  noisy on shared CI runners, so CI invokes this with a wide ``--tol``
  while keeping the energy gate strict.
* **energy per token** — derived from the ``metrics_snapshot`` the
  benchmark's metrics scenario embeds ((prefill + decode joules) /
  (prefill + decode tokens)).  This is virtual-clock accounting, fully
  deterministic, so ``--energy-tol`` stays at 10%: a regression here
  means the serving engine actually bills more energy for the same
  work, not that the runner was busy.

A fourth gate applies to ``engine_mesh_*`` rows in the *fresh* run (when
present): the mesh-sharded serving scenario embeds its energy-per-token
ratio against the unsharded engine, and that ratio must sit within
``--energy-tol`` of 1.0 in both directions.  Sharded serving is bit-exact
by construction (the ratio is 1.0 when the PR 10 invariant holds), so any
drift means the sharded data plane changed the work it bills — not noise.

A third gate applies to ``engine_prefix_cache_*`` rows in the *fresh* run
(when present): the shared-system-prompt burst must compute at least
``--prefix-min-saved`` fewer prefill tokens than its cold-cache twin and
bill strictly less energy per request.  Both values are deterministic
(virtual-clock, token-count arithmetic), so a failure means the cache
stopped matching — not noise.

Rows missing on either side are reported and skipped (benchmarks gain
scenarios over time); exit status is 1 iff any gate fails.
"""
import argparse
import json
import math
import re
import sys


def _energy_per_token(snap):
    e = sum(v for k, v in snap.items()
            if k.startswith("greenllm_energy_joules_total")
            and ('phase="prefill"' in k or 'phase="decode"' in k))
    t = sum(v for k, v in snap.items()
            if k.startswith("greenllm_tokens_total"))
    return e / t if t else math.nan


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline",
                    default="benchmarks/BENCH_serving_engine.json")
    ap.add_argument("--fresh", required=True,
                    help="--json output of a fresh benchmark run")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="max relative us_per_call slowdown per row")
    ap.add_argument("--energy-tol", type=float, default=0.10,
                    help="max relative energy-per-token increase")
    ap.add_argument("--prefix-min-saved", type=float, default=0.30,
                    help="min prefill_tokens_saved_frac for "
                         "engine_prefix_cache_* rows in the fresh run")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    base_rows = {r["name"]: float(r["us_per_call"])
                 for r in base.get("rows", [])}
    fresh_rows = {r["name"]: float(r["us_per_call"])
                  for r in fresh.get("rows", [])}

    failures = []
    compared = 0
    for name in sorted(base_rows):
        if name not in fresh_rows:
            print(f"skip {name}: not in fresh run")
            continue
        b, fr = base_rows[name], fresh_rows[name]
        ratio = (fr - b) / b
        bad = ratio > args.tol
        print(f"{'FAIL' if bad else '  ok'} {name}: "
              f"{b:.1f} -> {fr:.1f} us/call ({ratio:+.1%})")
        if bad:
            failures.append(f"{name} slowed {ratio:+.1%} (tol {args.tol:.0%})")
        compared += 1
    for name in sorted(set(fresh_rows) - set(base_rows)):
        print(f"new  {name}: {fresh_rows[name]:.1f} us/call (no baseline)")

    for row in fresh.get("rows", []):
        if "prefix_cache" not in row["name"]:
            continue
        derived = row.get("derived", "")
        m = re.search(r"prefill_tokens_saved_frac=([0-9.]+)", derived)
        e = re.search(r"energy_per_req_vs_cold=([0-9.]+)", derived)
        if not m or not e:
            failures.append(f"{row['name']}: derived metrics missing "
                            f"from {derived!r}")
            continue
        saved, eratio = float(m.group(1)), float(e.group(1))
        bad = saved < args.prefix_min_saved or eratio >= 1.0
        print(f"{'FAIL' if bad else '  ok'} {row['name']}: "
              f"saved_frac={saved:.3f} (min {args.prefix_min_saved:.2f}), "
              f"energy_per_req={eratio:.3f}x cold (must be < 1)")
        if bad:
            failures.append(
                f"{row['name']} prefix-cache win below floor: "
                f"saved_frac={saved:.3f}, energy ratio={eratio:.3f}")

    for row in fresh.get("rows", []):
        if not row["name"].startswith("engine_mesh_"):
            continue
        derived = row.get("derived", "")
        m = re.search(r"energy_per_tok_vs_unsharded=([0-9.]+)", derived)
        if not m:
            failures.append(f"{row['name']}: energy parity metric missing "
                            f"from {derived!r}")
            continue
        ratio = float(m.group(1))
        bad = abs(ratio - 1.0) > args.energy_tol
        print(f"{'FAIL' if bad else '  ok'} {row['name']}: "
              f"energy_per_token={ratio:.4f}x unsharded "
              f"(band ±{args.energy_tol:.0%})")
        if bad:
            failures.append(
                f"{row['name']} energy-per-token parity broken: "
                f"{ratio:.4f}x unsharded")

    bs = base.get("metrics_snapshot")
    fs = fresh.get("metrics_snapshot")
    if bs and fs:
        eb, ef = _energy_per_token(bs), _energy_per_token(fs)
        ratio = (ef - eb) / eb
        bad = ratio > args.energy_tol
        print(f"{'FAIL' if bad else '  ok'} energy_per_token: "
              f"{eb * 1e3:.4f} -> {ef * 1e3:.4f} mJ/tok ({ratio:+.1%})")
        if bad:
            failures.append(f"energy per token rose {ratio:+.1%} "
                            f"(tol {args.energy_tol:.0%})")
    else:
        print("skip energy_per_token: metrics_snapshot missing on "
              f"{'baseline' if not bs else 'fresh'} side")

    if not compared:
        failures.append("no common rows between baseline and fresh run")
    if failures:
        print(f"\n{len(failures)} regression(s):", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print(f"\nall gates passed ({compared} rows compared)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
