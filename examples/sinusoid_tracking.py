"""Reproduce paper Fig. 1: decode-clock tracking under a sinusoidal TPS load.

Prints an ASCII strip chart of the GreenLLM clock vs the defaultNV governor.

    PYTHONPATH=src python examples/sinusoid_tracking.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.common import HW, make_decode_controller, run_decode_bench
from repro.core import MaxFreqController
from repro.data import sinusoidal_decode_load


def strip(vals, lo, hi, width=60):
    x = np.clip((np.asarray(vals) - lo) / (hi - lo), 0, 1)
    return ["#" * int(v * width) for v in x]


def main():
    _, tps_series = sinusoidal_decode_load()
    grid = np.arange(0, 120, 0.5)
    tps_fn = lambda t: float(np.interp(t % 120.0, grid, tps_series))

    green = run_decode_bench("qwen3-14b", make_decode_controller("qwen3-14b"),
                             tps_fn, 120.0)
    base = run_decode_bench("qwen3-14b", MaxFreqController(HW), tps_fn, 120.0)

    # sample at 2s intervals
    gt = np.asarray([x[0] for x in green["freqs"]])
    gf = np.asarray([x[1] for x in green["freqs"]])
    gl = np.asarray([x[2] for x in green["freqs"]])
    print("t(s)   TPS    GreenLLM clock (MHz)  [defaultNV stays at "
          f"{HW.f_max:.0f} MHz]")
    for t in np.arange(0, 120, 4.0):
        i = int(np.searchsorted(gt, t))
        if i >= len(gf):
            break
        bar = "#" * int((gf[i] - HW.f_min) / (HW.f_max - HW.f_min) * 50)
        print(f"{t:5.0f} {gl[i]:6.0f}  {gf[i]:6.0f} |{bar}")
    print(f"\np99 TBT: GreenLLM {green['tbt_p99']*1e3:.1f} ms  "
          f"defaultNV {base['tbt_p99']*1e3:.1f} ms  (SLO 100 ms)")
    print(f"decode energy saving: "
          f"{100 * (1 - green['energy_j'] / base['energy_j']):.1f}%")


if __name__ == "__main__":
    main()
