"""Train a ~100M-parameter dense model for a few hundred steps on CPU.

Uses the real training substrate (AdamW + cosine schedule + microbatch
gradient accumulation + checkpointing) over a synthetic token pipeline.

    PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, NOSHARD
from repro.training import (AdamWConfig, init_train_state, make_train_step,
                            save_checkpoint)

# ~100M params: 14L x d640 (GQA 10/5) x ff2560, 32k vocab
CFG = ModelConfig(
    name="repro-100m", arch_type="dense", num_layers=14, d_model=640,
    num_heads=10, num_kv_heads=5, head_dim=64, d_ff=2560, vocab_size=32_000,
    tie_embeddings=True, max_seq=1024,
)


def data_stream(batch, seq, vocab, seed=0):
    """Synthetic structured data: noisy arithmetic-progression sequences —
    learnable (loss falls well below uniform) without any external dataset."""
    rng = np.random.default_rng(seed)
    while True:
        start = rng.integers(0, vocab - 1, (batch, 1))
        step = rng.integers(1, 17, (batch, 1))
        seqs = (start + step * np.arange(seq)[None, :]) % vocab
        noise = rng.integers(0, vocab, (batch, seq))
        mask = rng.random((batch, seq)) < 0.02
        yield {"tokens": jnp.asarray(np.where(mask, noise, seqs), jnp.int32)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="results/train_small/ckpt.msgpack")
    args = ap.parse_args()

    print(f"model: {CFG.param_count()/1e6:.1f}M params")
    state = init_train_state(jax.random.PRNGKey(0), CFG)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(CFG, opt_cfg, NOSHARD,
                                      num_microbatches=2))
    stream = data_stream(args.batch, args.seq, CFG.vocab_size)
    t0 = time.time()
    for i in range(args.steps):
        state, m = step_fn(state, next(stream))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):7.4f}  "
                  f"lr {float(m['lr']):.2e}  gnorm {float(m['grad_norm']):8.2f}  "
                  f"({time.time()-t0:5.1f}s)")
    os.makedirs(os.path.dirname(args.ckpt), exist_ok=True)
    save_checkpoint(args.ckpt, state["params"])
    print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
