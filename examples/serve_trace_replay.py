"""End-to-end serving driver (the paper's kind of workload): replay an
Alibaba-chat-like trace against the serving node under all three governors
and print the paper's Table-3-style comparison, then run a short burst of
*real* JAX inference (batched requests through the actual model) with the
same control plane — everything driven through the ``serving.api.Server``
front door (submit → stream → cancel) and reported as the shared typed
``ServingReport``.

    PYTHONPATH=src python examples/serve_trace_replay.py [--trace chat_5qps]
        [--arch qwen3-14b] [--duration 120] [--cluster] [--prefix-cache]
        [--kill-replica decode0] [--kill-frac 0.4] [--handoff-failures 3]

``--prefix-cache`` adds a shared-system-prompt burst served twice — cold
cache vs warm — asserting bit-identical tokens and printing the hit rate
plus the prefill joules the cache saved on the full-size plant model.

``--cluster`` adds a disaggregated 1-prefill + 1-decode replica cluster
(paged-KV handoff, per-phase DVFS) replaying an azure_code burst against a
2x-colocated max-frequency baseline at equal replica count.

``--kill-replica`` / ``--handoff-failures`` inject deterministic faults
into that cluster run (``serving.faults``): the named replica is killed
partway through (``--kill-frac`` of the baseline makespan) and the first N
handoff imports fail transiently.  The run must still drain completely —
killed streams are recomputed on survivors, failed imports retry with
capped backoff — which is the crash-recovery smoke CI exercises.
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core import (AlertEngine, EnergyLedger, MetricsRegistry,
                        SamplingParams, SLOConfig, Tracer,
                        read_timeline_jsonl, verify_conservation)
from repro.data import get_trace
from repro.launch.serve import default_alert_rules
from repro.serving import (EngineConfig, FaultPlan, HandoffFailure,
                           ReplicaKill, Server, ServingCluster,
                           ServingEngine)
from repro.sim import ReplayConfig, replay


def replay_burst(server, trace, vocab, *, max_len=192, out_cap=48,
                 keep_arrivals=True):
    """Replay ``trace`` through any backend behind ``server`` (the same
    code path drives a single engine and a cluster — the point of the
    API).  ``keep_arrivals=False`` injects everything at t=0 (a pure
    burst: maximum pool pressure)."""
    rng = np.random.default_rng(0)
    for r in trace:
        plen = min(r.prompt_len, max_len // 2)
        server.submit(rng.integers(0, vocab, size=plen),
                      SamplingParams(max_tokens=min(r.output_len, out_cap)),
                      arrival=r.arrival if keep_arrivals else 0.0)
    return server.run()


def run_cluster(cfg, smoke, trace, *, max_len=192, kill_replica="",
                kill_frac=0.4, handoff_failures=0):
    """Disaggregated greenllm cluster vs 2x-colocated defaultNV on the same
    azure_code-style burst of real JAX inference — optionally with injected
    faults (replica kill, transient handoff-import failures), which the
    cluster must recover from without losing a single request."""
    from repro.models import init_params
    import jax
    params = init_params(jax.random.PRNGKey(0), smoke)

    def build(governor, faults=None, alerts=None, **kw):
        cl = ServingCluster(
            smoke, params=params, plant_cfg=cfg, faults=faults,
            ecfg=EngineConfig(max_batch=8, max_len=max_len,
                              governor=governor), **kw)
        return cl, Server(cl, alerts=alerts)

    _, bsrv = build("defaultnv", n_prefill=0, n_decode=0, n_colocated=2)
    base = replay_burst(bsrv, trace, smoke.vocab_size, max_len=max_len)

    events = []
    if kill_replica:
        # the baseline makespan is the fault horizon: same order of
        # magnitude as the disaggregated run's own clock
        events.append(ReplicaKill(at=kill_frac * base.duration_s,
                                  replica=kill_replica))
    if handoff_failures > 0:
        events.append(HandoffFailure(at=0.0, count=handoff_failures))
    plan = FaultPlan(events) if events else None

    reg = MetricsRegistry(snapshot_min_dt=0.002)
    tr = Tracer()
    ledger = EnergyLedger()
    alerts = AlertEngine(reg, default_alert_rules(SLOConfig()), tracer=tr)
    cl, srv = build("greenllm", faults=plan, alerts=alerts,
                    n_prefill=1, n_decode=1,
                    metrics=reg, tracer=tr, ledger=ledger)
    rep = replay_burst(srv, trace, smoke.vocab_size, max_len=max_len)
    assert rep.completed == base.completed == len(trace), \
        "cluster must drain the burst completely (zero stalls)"
    if plan is not None:
        print(f"faults: kills={[(n, round(t, 3)) for n, t, _ in cl.kills]}  "
              f"import_retries={cl.import_retries}  "
              f"fired={[k for k, _, _ in plan.log]}")
        assert not kill_replica or cl.kills, "scheduled kill never fired"
        assert cl.import_retries >= handoff_failures, \
            "injected import failures must surface as retries"

    print(f"{'replica':12s} {'role':10s} {'E_pre J':>9s} {'E_dec J':>9s} "
          f"{'E_idle J':>9s} {'tok pre/dec':>12s} {'handoffs':>9s}")
    for row in rep.replicas:
        print(f"{row.name:12s} {row.role:10s} "
              f"{row.prefill_energy_j:9.1f} {row.decode_energy_j:9.1f} "
              f"{row.idle_energy_j:9.1f} "
              f"{row.prefill_tokens:5d}/{row.decode_tokens:5d} "
              f"{row.exported + row.imported:9d}")
    save = 100 * (1 - rep.total_energy_j / base.total_energy_j)
    print(f"completed={rep.completed}/{len(trace)}  "
          f"handoffs={rep.migrated}  preempted={rep.preempted}  "
          f"makespan={rep.duration_s:.2f}s")
    print(f"TTFT pass={rep.ttft_pass * 100:.0f}%  "
          f"TBT pass={rep.tbt_pass * 100:.0f}%  "
          f"p95 TBT={rep.p95_tbt_s * 1e3:.1f}ms")
    print(f"energy: disaggregated={rep.total_energy_j / 1e3:.2f}kJ  "
          f"colocated@fmax={base.total_energy_j / 1e3:.2f}kJ  "
          f"saving={save:.1f}%")

    # --- per-request energy attribution + counterfactual savings -----------
    # the ledger splits every metered joule across resident requests (idle
    # stays an explicit unattributed pool); conservation against the report
    # rows is *bitwise*, even across kills and handoffs
    summary = verify_conservation(ledger, rep.replicas)
    pool = sum(s["idle_pool_j"] for s in summary)
    denom = max(rep.total_energy_j + rep.energy_saved_j, 1e-9)
    print(f"attribution: conservation exact on {len(summary)} replicas  "
          f"idle_pool={pool:.1f}J (unattributed)  "
          f"saved_vs_fmax={rep.energy_saved_j:.1f}J "
          f"({100 * rep.energy_saved_j / denom:.1f}% of a max-freq run)")
    by_rid = {x["rid"]: x for x in ledger.rows()}
    for r in sorted(rep.requests, key=lambda q: -q.energy_j)[:5]:
        row = by_rid[r.rid]
        carried = (f"  carried_from={','.join(row['carried_from'])}"
                   if row["carried_from"] else "")
        print(f"  rid={r.rid:<4d} E={r.energy_j:7.2f}J  "
              f"saved={r.energy_saved_j:6.2f}J  "
              f"replicas={','.join(row['replicas'])}{carried}")
    if plan is None:
        assert rep.total_energy_j <= base.total_energy_j, \
            "per-phase DVFS must not cost energy vs the max-freq baseline"
    else:
        # with a kill the survivors recompute lost streams, so the energy
        # win is not guaranteed — conservation across the kill is (dead
        # replicas stop billing at their kill snapshot)
        assert abs(sum(r.energy_j for r in rep.replicas)
                   - rep.total_energy_j) < 1e-6 * max(rep.total_energy_j, 1)

    # --- replayable observability timeline ---------------------------------
    # every metric is queryable at any virtual-clock instant, and every
    # frequency change across the timeline has a logged decision reason
    import os
    import tempfile
    out = os.path.join(tempfile.gettempdir(),
                       "cluster_metrics.timeline.jsonl")
    n_snap = reg.write_timeline_jsonl(out)
    assert read_timeline_jsonl(out) == reg.timeline, \
        "timeline JSONL must round-trip exactly"
    mid = rep.duration_s / 2
    snap = reg.query(mid)

    def at(prefix, replica):
        return next((v for k, v in snap.items() if k.startswith(prefix)
                     and f'replica="{replica}"' in k), float("nan"))

    print(f"observability: {n_snap} snapshots -> {out}  "
          f"({len(tr)} trace records)")
    print(f"state @ t={mid:.3f}s (mid-run query):")
    for row in rep.replicas:
        e_mid = sum(v for k, v in snap.items()
                    if k.startswith("greenllm_energy_joules_total")
                    and f'replica="{row.name}"' in k)
        f_mid = at("greenllm_frequency_mhz", row.name)
        occ_mid = at("greenllm_page_occupancy", row.name)
        p99_mid = at("greenllm_tbt_p99_seconds", row.name)
        print(f"  {row.name:10s} f={f_mid:6.0f}MHz E={e_mid:8.1f}J "
              f"occ={occ_mid * 100:5.1f}% p99_tbt={p99_mid * 1e3:.1f}ms")
    audited = 0
    for row in rep.replicas:
        key = f'greenllm_frequency_mhz{{replica="{row.name}"}}'
        series = reg.series(key)
        phase = "prefill" if row.role == "prefill" else "decode"
        for (t0, v0), (t1, v1) in zip(series, series[1:]):
            if v1 == v0:
                continue
            d = tr.decision_at(t1, row.name, phase=phase)
            assert d is not None, \
                f"frequency change on {row.name} @ {t1:.4f}s has no " \
                f"logged DVFS decision"
            assert abs(d.freq_mhz - v1) < 1e-6, \
                f"{row.name} @ {t1:.4f}s: gauge {v1} != decided " \
                f"{d.freq_mhz} ({d.reason})"
            audited += 1
    reasons = sorted({d.reason for d in tr.decisions()})
    print(f"DVFS audit: {audited} frequency changes, each with a logged "
          f"reason; reasons seen: {reasons}")
    n_alert = alerts.audit()
    fired = [a for a in alerts.log if a.fired]
    print(f"alerts: {len(fired)} firing transition(s), {n_alert} audited "
          f"against the timeline"
          + (f"; fired: {sorted({a.rule for a in fired})}" if fired else ""))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="chat_5qps")
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--cluster", action="store_true",
                    help="add the disaggregated prefill/decode cluster "
                         "replay vs the colocated max-frequency baseline")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="add a shared-system-prompt burst served warm "
                         "(prefix cache on) vs cold, printing hit rate and "
                         "prefill joules saved (tokens asserted identical)")
    ap.add_argument("--kill-replica", default="",
                    help="with --cluster: kill this replica (e.g. decode0) "
                         "partway through and recover on survivors")
    ap.add_argument("--kill-frac", type=float, default=0.4,
                    help="kill time as a fraction of the baseline makespan")
    ap.add_argument("--handoff-failures", type=int, default=0,
                    help="with --cluster: fail the first N handoff imports "
                         "(retried with capped exponential backoff)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    trace = get_trace(args.trace, duration=args.duration)
    print(f"=== trace replay: {args.trace} x {args.arch} "
          f"({len(trace)} requests, {args.duration:.0f}s) ===")
    print(f"{'governor':14s} {'TTFT%':>7s} {'TBT%':>7s} {'E_pre kJ':>9s} "
          f"{'E_dec kJ':>9s} {'dE%':>7s} {'tok/s':>7s}")
    base = None
    deg = 1 if cfg.is_subquadratic else 2
    for gov in ("defaultNV", "prefillsplit", "greenllm"):
        m = replay(cfg, trace, ReplayConfig(governor=gov,
                                            latency_fit_degree=deg))
        if base is None:
            base = m.total_energy_j
        print(f"{gov:14s} {m.ttft_pass*100:7.1f} {m.tbt_pass*100:7.1f} "
              f"{m.prefill_energy_j/1e3:9.1f} {m.decode_energy_j/1e3:9.1f} "
              f"{100*(1-m.total_energy_j/base):7.2f} "
              f"{m.throughput_tok_s:7.0f}")

    # --- real JAX execution with the same control plane ------------------------
    # streamed through the request-lifecycle API: tokens arrive in decode-
    # block bursts while the rest of the batch is still in flight
    print("\n=== real-execution burst (reduced model, GreenLLM control) ===")
    smoke = cfg.smoke()
    srv = Server(ServingEngine(smoke,
                               ecfg=EngineConfig(max_batch=8, max_len=192),
                               plant_cfg=cfg))
    rng = np.random.default_rng(0)
    handles = [srv.submit(rng.integers(0, smoke.vocab_size,
                                       size=int(rng.integers(16, 80))),
                          SamplingParams(
                              max_tokens=int(rng.integers(16, 60))))
               for _ in range(12)]
    first = sum(1 for _ in handles[0].tokens())   # stream one to completion
    rep = srv.run()                               # drain the rest
    print(f"streamed {first} tokens from request 0 while "
          f"{rep.n_requests - 1} others decoded")
    print(rep.summary())

    # --- paged engine on a long-prompt-heavy trace -----------------------------
    # azure_code prompts are long (code context); on half the dense K/V memory
    # the paged engine still fills every batch slot, long prompts admit through
    # chunked prefill (no eager fallback), and pool pressure preempts +
    # recomputes instead of refusing admission.
    print("\n=== paged burst: azure_code prompt/output mix, half K/V memory ===")
    code_trace = get_trace("azure_code8", duration=args.duration)
    max_len, page_size, batch = 192, 16, 8
    num_pages = (batch * max_len // page_size) // 2 + 1
    peng = ServingEngine(smoke, plant_cfg=cfg, ecfg=EngineConfig(
        max_batch=batch, max_len=max_len, paged=True, page_size=page_size,
        num_pages=num_pages))
    pst = replay_burst(Server(peng), code_trace[:16], smoke.vocab_size,
                       max_len=max_len, keep_arrivals=False)
    pool = peng.pager.occupancy()["pages_total"]   # page 0 is scratch
    dense_equiv = (pool * page_size) // max_len
    print(f"completed={pst.completed}  preempted={pst.preempted}  "
          f"pool={pool}p ({dense_equiv} dense-equivalent rows "
          f"for batch={batch})")
    print(f"peak occupancy={pst.page_occupancy_peak * 100:.0f}%")
    print(f"E_prefill={pst.prefill_energy_j/1e3:.2f}kJ "
          f"({pst.prefill_tokens} tok)  "
          f"E_decode={pst.decode_energy_j/1e3:.2f}kJ "
          f"({pst.decode_tokens} tok)  "
          f"p95 TBT={pst.p95_tbt_s * 1e3:.1f}ms")

    # --- prefix cache: shared-system-prompt burst, warm vs cold ---------------
    # chat/RAG traffic re-prefills the same system prompt per request; with
    # --prefix-cache the paged engine serves the shared head from cached
    # pages (bit-identical tokens, asserted) and the skipped prefill work
    # shows up directly as joules on the full-size plant model
    if args.prefix_cache:
        print("\n=== prefix cache: shared 80-token system prompt, "
              "12 requests ===")
        import dataclasses
        # f32 compute: a hit replays the prompt through chunked prefill
        # against cached pages while the cold run one-shots it — bitwise
        # equal in f32, an ulp apart in bf16 (see tests/test_prefix_cache)
        pc_smoke = dataclasses.replace(smoke, dtype="float32")

        def pc_burst(on):
            eng = ServingEngine(pc_smoke, plant_cfg=cfg, ecfg=EngineConfig(
                max_batch=8, max_len=192, paged=True, prefix_cache=on))
            psrv = Server(eng)
            prng = np.random.default_rng(7)
            head = prng.integers(0, smoke.vocab_size, size=80)
            for _ in range(12):
                tail = prng.integers(0, smoke.vocab_size,
                                     size=int(prng.integers(4, 16)))
                psrv.submit(np.concatenate([head, tail]),
                            SamplingParams(max_tokens=16))
            return eng, psrv.run()

        ceng, crep = pc_burst(False)
        weng, wrep = pc_burst(True)
        assert [q.tokens for q in weng.requests] == \
            [q.tokens for q in ceng.requests], \
            "prefix-cache tokens must match the cold run"
        st = weng.prefix_cache.stats()
        saved_j = crep.prefill_energy_j - wrep.prefill_energy_j
        saved_tok = crep.prefill_tokens - wrep.prefill_tokens
        print(f"hit_rate={st['hit_rate'] * 100:.0f}% "
              f"({st['hits']} hits / {st['misses']} misses, "
              f"{st['hit_tokens']} prompt tokens from cache)")
        print(f"prefill: {crep.prefill_tokens} -> {wrep.prefill_tokens} "
              f"tokens ({saved_tok} skipped)  "
              f"energy: {crep.prefill_energy_j:.1f}J -> "
              f"{wrep.prefill_energy_j:.1f}J "
              f"(saved {saved_j:.1f}J, "
              f"{100 * saved_j / crep.prefill_energy_j:.0f}% of prefill)")
        assert wrep.prefill_tokens < crep.prefill_tokens, \
            "warm run must prefill fewer tokens"

    # --- disaggregated prefill/decode cluster on the azure_code burst ---------
    if args.cluster:
        print("\n=== disaggregated cluster: 1 prefill + 1 decode replica, "
              "paged-KV handoff, per-phase DVFS ===")
        run_cluster(cfg, smoke, code_trace[:16],
                    kill_replica=args.kill_replica,
                    kill_frac=args.kill_frac,
                    handoff_failures=args.handoff_failures)


if __name__ == "__main__":
    main()
