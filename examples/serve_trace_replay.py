"""End-to-end serving driver (the paper's kind of workload): replay an
Alibaba-chat-like trace against the serving node under all three governors
and print the paper's Table-3-style comparison, then run a short burst of
*real* JAX inference (batched requests through the actual model) with the
same control plane.

    PYTHONPATH=src python examples/serve_trace_replay.py [--trace chat_5qps]
        [--arch qwen3-14b] [--duration 120] [--cluster]

``--cluster`` adds a disaggregated 1-prefill + 1-decode replica cluster
(paged-KV handoff, per-phase DVFS) replaying an azure_code burst against a
2x-colocated max-frequency baseline at equal replica count.
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core import Request
from repro.data import get_trace
from repro.serving import EngineConfig, ServingEngine, ServingCluster
from repro.sim import ReplayConfig, replay


def run_cluster(cfg, smoke, trace, *, max_len=192):
    """Disaggregated greenllm cluster vs 2x-colocated defaultNV on the same
    azure_code-style burst of real JAX inference."""
    from repro.models import init_params
    import jax
    params = init_params(jax.random.PRNGKey(0), smoke)

    def build(governor, **kw):
        return ServingCluster(
            smoke, params=params, plant_cfg=cfg,
            ecfg=EngineConfig(max_batch=8, max_len=max_len,
                              governor=governor), **kw)

    def replay_on(cl):
        rng = np.random.default_rng(0)
        for i, r in enumerate(trace):
            cl.submit(Request(
                rid=i, arrival=r.arrival,
                prompt_len=min(r.prompt_len, max_len // 2),
                output_len=min(r.output_len, 48)),
                rng.integers(0, smoke.vocab_size,
                             size=min(r.prompt_len, max_len // 2)))
        return cl.run_until_drained()

    base = replay_on(build("defaultnv", n_prefill=0, n_decode=0,
                           n_colocated=2))
    st = replay_on(build("greenllm", n_prefill=1, n_decode=1))
    assert st["completed"] == base["completed"] == len(trace), \
        "cluster must drain the burst completely (zero stalls)"

    print(f"{'replica':12s} {'role':10s} {'E_pre J':>9s} {'E_dec J':>9s} "
          f"{'E_idle J':>9s} {'tok pre/dec':>12s} {'handoffs':>9s}")
    for row in st["replicas"]:
        print(f"{row['name']:12s} {row['role']:10s} "
              f"{row['prefill_energy_j']:9.1f} {row['decode_energy_j']:9.1f} "
              f"{row['idle_energy_j']:9.1f} "
              f"{row['prefill_tokens']:5d}/{row['decode_tokens']:5d} "
              f"{row['exported'] + row['imported']:9d}")
    save = 100 * (1 - st["energy_j"] / base["energy_j"])
    print(f"completed={st['completed']}/{len(trace)}  "
          f"handoffs={st['handoffs']}  preempted={st['preempted']}  "
          f"makespan={st['makespan_s']:.2f}s")
    print(f"TTFT pass={st['ttft_pass']*100:.0f}%  "
          f"TBT pass={st['tbt_pass']*100:.0f}%  "
          f"p95 TBT={st['p95_tbt_ms']:.1f}ms")
    print(f"energy: disaggregated={st['energy_j']/1e3:.2f}kJ  "
          f"colocated@fmax={base['energy_j']/1e3:.2f}kJ  "
          f"saving={save:.1f}%")
    assert st["energy_j"] <= base["energy_j"], \
        "per-phase DVFS must not cost energy vs the max-freq baseline"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="chat_5qps")
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--cluster", action="store_true",
                    help="add the disaggregated prefill/decode cluster "
                         "replay vs the colocated max-frequency baseline")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    trace = get_trace(args.trace, duration=args.duration)
    print(f"=== trace replay: {args.trace} x {args.arch} "
          f"({len(trace)} requests, {args.duration:.0f}s) ===")
    print(f"{'governor':14s} {'TTFT%':>7s} {'TBT%':>7s} {'E_pre kJ':>9s} "
          f"{'E_dec kJ':>9s} {'dE%':>7s} {'tok/s':>7s}")
    base = None
    deg = 1 if cfg.is_subquadratic else 2
    for gov in ("defaultNV", "prefillsplit", "greenllm"):
        m = replay(cfg, trace, ReplayConfig(governor=gov,
                                            latency_fit_degree=deg))
        if base is None:
            base = m.total_energy_j
        print(f"{gov:14s} {m.ttft_pass*100:7.1f} {m.tbt_pass*100:7.1f} "
              f"{m.prefill_energy_j/1e3:9.1f} {m.decode_energy_j/1e3:9.1f} "
              f"{100*(1-m.total_energy_j/base):7.2f} "
              f"{m.throughput_tok_s:7.0f}")

    # --- real JAX execution with the same control plane ------------------------
    print("\n=== real-execution burst (reduced model, GreenLLM control) ===")
    smoke = cfg.smoke()
    eng = ServingEngine(smoke, ecfg=EngineConfig(max_batch=8, max_len=192),
                        plant_cfg=cfg)
    rng = np.random.default_rng(0)
    for i in range(12):
        eng.submit(Request(rid=i, arrival=0.0,
                           prompt_len=int(rng.integers(16, 80)),
                           output_len=int(rng.integers(16, 60))))
    stats = eng.run_until_drained()
    print(f"completed={stats['completed']}  virtual_time={stats['vtime_s']:.2f}s  "
          f"energy={stats['energy_j']/1e3:.2f}kJ  "
          f"p95 TBT={stats['p95_tbt_ms']:.1f}ms  clock={stats['freq_mhz']:.0f}MHz")

    # --- paged engine on a long-prompt-heavy trace -----------------------------
    # azure_code prompts are long (code context); on half the dense K/V memory
    # the paged engine still fills every batch slot, long prompts admit through
    # chunked prefill (no eager fallback), and pool pressure preempts +
    # recomputes instead of refusing admission.
    print("\n=== paged burst: azure_code prompt/output mix, half K/V memory ===")
    code_trace = get_trace("azure_code8", duration=args.duration)
    max_len, page_size, batch = 192, 16, 8
    num_pages = (batch * max_len // page_size) // 2 + 1
    peng = ServingEngine(smoke, plant_cfg=cfg, ecfg=EngineConfig(
        max_batch=batch, max_len=max_len, paged=True, page_size=page_size,
        num_pages=num_pages))
    for i, r in enumerate(code_trace[:16]):
        peng.submit(Request(rid=1000 + i, arrival=0.0,
                            prompt_len=min(r.prompt_len, max_len // 2),
                            output_len=min(r.output_len, 48)))
    st = peng.run_until_drained(max_steps=50_000)
    dense_equiv = (st["pages_total"] * page_size) // max_len
    print(f"completed={st['completed']}  preempted={st['preempted']}  "
          f"pool={st['pages_total']}p ({dense_equiv} dense-equivalent rows "
          f"for batch={batch})")
    print(f"occupancy(now)={st['page_occupancy']*100:.0f}%  "
          f"peak={st['page_occupancy_peak']*100:.0f}%  "
          f"fragmentation={st['page_fragmentation']*100:.0f}%")
    print(f"E_prefill={st['prefill_energy_j']/1e3:.2f}kJ ({st['prefill_tokens']} tok)  "
          f"E_decode={st['decode_energy_j']/1e3:.2f}kJ ({st['decode_tokens']} tok)  "
          f"p95 TBT={st['p95_tbt_ms']:.1f}ms")

    # --- disaggregated prefill/decode cluster on the azure_code burst ---------
    if args.cluster:
        print("\n=== disaggregated cluster: 1 prefill + 1 decode replica, "
              "paged-KV handoff, per-phase DVFS ===")
        run_cluster(cfg, smoke, code_trace[:16])


if __name__ == "__main__":
    main()
