"""End-to-end serving driver (the paper's kind of workload): replay an
Alibaba-chat-like trace against the serving node under all three governors
and print the paper's Table-3-style comparison, then run a short burst of
*real* JAX inference (batched requests through the actual model) with the
same control plane.

    PYTHONPATH=src python examples/serve_trace_replay.py [--trace chat_5qps]
        [--arch qwen3-14b] [--duration 120]
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core import Request
from repro.data import get_trace
from repro.serving import EngineConfig, ServingEngine
from repro.sim import ReplayConfig, replay


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="chat_5qps")
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--duration", type=float, default=120.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    trace = get_trace(args.trace, duration=args.duration)
    print(f"=== trace replay: {args.trace} x {args.arch} "
          f"({len(trace)} requests, {args.duration:.0f}s) ===")
    print(f"{'governor':14s} {'TTFT%':>7s} {'TBT%':>7s} {'E_pre kJ':>9s} "
          f"{'E_dec kJ':>9s} {'dE%':>7s} {'tok/s':>7s}")
    base = None
    deg = 1 if cfg.is_subquadratic else 2
    for gov in ("defaultNV", "prefillsplit", "greenllm"):
        m = replay(cfg, trace, ReplayConfig(governor=gov,
                                            latency_fit_degree=deg))
        if base is None:
            base = m.total_energy_j
        print(f"{gov:14s} {m.ttft_pass*100:7.1f} {m.tbt_pass*100:7.1f} "
              f"{m.prefill_energy_j/1e3:9.1f} {m.decode_energy_j/1e3:9.1f} "
              f"{100*(1-m.total_energy_j/base):7.2f} "
              f"{m.throughput_tok_s:7.0f}")

    # --- real JAX execution with the same control plane ------------------------
    print("\n=== real-execution burst (reduced model, GreenLLM control) ===")
    smoke = cfg.smoke()
    eng = ServingEngine(smoke, ecfg=EngineConfig(max_batch=8, max_len=192),
                        plant_cfg=cfg)
    rng = np.random.default_rng(0)
    for i in range(12):
        eng.submit(Request(rid=i, arrival=0.0,
                           prompt_len=int(rng.integers(16, 80)),
                           output_len=int(rng.integers(16, 60))))
    stats = eng.run_until_drained()
    print(f"completed={stats['completed']}  virtual_time={stats['vtime_s']:.2f}s  "
          f"energy={stats['energy_j']/1e3:.2f}kJ  "
          f"p95 TBT={stats['p95_tbt_ms']:.1f}ms  clock={stats['freq_mhz']:.0f}MHz")

    # --- paged engine on a long-prompt-heavy trace -----------------------------
    # azure_code prompts are long (code context); on half the dense K/V memory
    # the paged engine still fills every batch slot, long prompts admit through
    # chunked prefill (no eager fallback), and pool pressure preempts +
    # recomputes instead of refusing admission.
    print("\n=== paged burst: azure_code prompt/output mix, half K/V memory ===")
    code_trace = get_trace("azure_code8", duration=args.duration)
    max_len, page_size, batch = 192, 16, 8
    num_pages = (batch * max_len // page_size) // 2 + 1
    peng = ServingEngine(smoke, plant_cfg=cfg, ecfg=EngineConfig(
        max_batch=batch, max_len=max_len, paged=True, page_size=page_size,
        num_pages=num_pages))
    for i, r in enumerate(code_trace[:16]):
        peng.submit(Request(rid=1000 + i, arrival=0.0,
                            prompt_len=min(r.prompt_len, max_len // 2),
                            output_len=min(r.output_len, 48)))
    st = peng.run_until_drained(max_steps=50_000)
    dense_equiv = (st["pages_total"] * page_size) // max_len
    print(f"completed={st['completed']}  preempted={st['preempted']}  "
          f"pool={st['pages_total']}p ({dense_equiv} dense-equivalent rows "
          f"for batch={batch})")
    print(f"occupancy(now)={st['page_occupancy']*100:.0f}%  "
          f"peak={st['page_occupancy_peak']*100:.0f}%  "
          f"fragmentation={st['page_fragmentation']*100:.0f}%")
    print(f"E_prefill={st['prefill_energy_j']/1e3:.2f}kJ ({st['prefill_tokens']} tok)  "
          f"E_decode={st['decode_energy_j']/1e3:.2f}kJ ({st['decode_tokens']} tok)  "
          f"p95 TBT={st['p95_tbt_ms']:.1f}ms")


if __name__ == "__main__":
    main()
