"""Quickstart: the GreenLLM control plane in ~60 lines.

Profiles a plant, fits the paper's compact models, solves the prefill
frequency optimization (Eq. 14), and runs the dual-loop decode controller
against a step change in load.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import (A100_SXM4_40G as HW, DualLoopController,
                        PrefillOptimizer)
from repro.sim import (PlantModel, profile_decode_table, profile_power,
                       profile_prefill_latency)

# 1. A plant: qwen3-14b served on a 2xA100 prefill worker -----------------------
cfg = get_config("qwen3-14b")
plant = PlantModel(cfg=cfg, hw=HW, n_chips=2, seed=0)

# 2. Offline profiling -> compact fitted models (paper Figs. 7-8) ----------------
lat = profile_prefill_latency(plant)             # t_ref(L) = aL^2 + bL + c
pwr = profile_power(plant)                       # P(f) cubic
print(f"latency fit:  a={lat.a:.3e}  b={lat.b:.3e}  c={lat.c:.3e}")
print(f"power fit:    P(f_max)={pwr.predict(HW.f_max):.0f} W  "
      f"P(f_min)={pwr.predict(HW.f_min):.0f} W")

# 3. Queueing-aware prefill clock selection (Eq. 12-14) ---------------------------
opt = PrefillOptimizer(lat, pwr, HW, HW.p_idle)
queue = [256, 512, 1024, 4096]                    # pending prompt lengths
for D in (0.25, 0.5, 1.0, 2.0):
    f, info = opt.choose_frequency(queue, D)
    print(f"deadline D={D:4.2f}s -> f*={f:6.0f} MHz  "
          f"busy={info['busy']*1e3:6.1f} ms  feasible={info['feasible']}")

# 4. Dual-loop decode controller under a load step (paper §3.3) -------------------
dplant = PlantModel(cfg=cfg, hw=HW, n_chips=1, seed=1)
table = profile_decode_table(dplant)
ctl = DualLoopController(HW, table)
t, last = 0.0, 0.03
for phase, tps in (("low", 400), ("high", 2400), ("low", 400)):
    for _ in range(300):
        f = ctl.maybe_tick(t)
        batch = max(int(np.ceil(tps * last)), 1)
        dur = dplant.decode_step_latency(batch, 640, f)
        ctl.record_tokens(t + dur, batch, dur)
        last, t = dur, t + dur
    print(f"load={phase:4s} ({tps:4d} TPS) -> clock {ctl.freq:6.0f} MHz, "
          f"TBT {last*1e3:.1f} ms (SLO 100 ms)")
